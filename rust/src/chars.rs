//! Arabic character handling: codepoints, normalization, fixed-width words.
//!
//! The paper processes 16-bit Arabic Unicode (U+0621..U+064A), strips
//! diacritics, treats bare alef `ا` and hamza-alef `أ` as equivalent, and
//! fixes the datapath width at 15 characters — the length of the longest
//! Arabic word (أفاستسقيناكموها). We mirror all of that here; this module is
//! the single source of truth the software stemmer, the HW simulator and the
//! PJRT encoding all share. It must agree exactly with
//! `python/compile/alphabet.py`.

/// Maximum word length in characters (paper: 15, the longest Arabic word).
pub const MAX_WORD: usize = 15;

/// Maximum prefix length examined by the datapath (paper: 5 registers).
pub const MAX_PREFIX: usize = 5;

/// Maximum suffix length examined by the datapath (paper: up to 9 letters,
/// bounded by the 15-register suffix array).
pub const MAX_SUFFIX: usize = 9;

/// Unicode codepoint used for padding / "U" (undefined) positions.
pub const PAD: u16 = 0;

// --- The Arabic block this system understands (paper §5.2) ---------------

pub const HAMZA: u16 = 0x0621;
pub const ALEF_MADDA: u16 = 0x0622;
pub const ALEF_HAMZA_ABOVE: u16 = 0x0623;
pub const WAW_HAMZA: u16 = 0x0624;
pub const ALEF_HAMZA_BELOW: u16 = 0x0625;
pub const YEH_HAMZA: u16 = 0x0626;
pub const ALEF: u16 = 0x0627;
pub const BEH: u16 = 0x0628;
pub const TEH_MARBUTA: u16 = 0x0629;
pub const TEH: u16 = 0x062A;
pub const THEH: u16 = 0x062B;
pub const JEEM: u16 = 0x062C;
pub const HAH: u16 = 0x062D;
pub const KHAH: u16 = 0x062E;
pub const DAL: u16 = 0x062F;
pub const THAL: u16 = 0x0630;
pub const REH: u16 = 0x0631;
pub const ZAIN: u16 = 0x0632;
pub const SEEN: u16 = 0x0633;
pub const SHEEN: u16 = 0x0634;
pub const SAD: u16 = 0x0635;
pub const DAD: u16 = 0x0636;
pub const TAH: u16 = 0x0637;
pub const ZAH: u16 = 0x0638;
pub const AIN: u16 = 0x0639;
pub const GHAIN: u16 = 0x063A;
pub const FEH: u16 = 0x0641;
pub const QAF: u16 = 0x0642;
pub const KAF: u16 = 0x0643;
pub const LAM: u16 = 0x0644;
pub const MEEM: u16 = 0x0645;
pub const NOON: u16 = 0x0646;
pub const HEH: u16 = 0x0647;
pub const WAW: u16 = 0x0648;
pub const ALEF_MAKSURA: u16 = 0x0649;
pub const YEH: u16 = 0x064A;

/// The seven letters that can start a verb as a prefix — the letters of
/// (فسألتني): Feh, Seen, Alef-Hamza, Lam, Teh, Noon, Yeh. Matches the VHDL
/// constant in the paper's Fig. 3.
pub const PREFIX_LETTERS: [u16; 7] = [ALEF_HAMZA_ABOVE, TEH, SEEN, FEH, LAM, NOON, YEH];

/// The nine letters that can end a verb as a suffix. The paper groups them
/// in one mnemonic word; the set below covers every suffix the paper's
/// examples exercise (يناكموها, ون, تم, ...): Alef, Teh, Heh, Kaf, Meem,
/// Waw, Noon, Yeh, Teh-Marbuta.
pub const SUFFIX_LETTERS: [u16; 9] = [ALEF, TEH, HEH, KAF, MEEM, WAW, NOON, YEH, TEH_MARBUTA];

/// The five letters that can appear inside a root as an infix (أوتني):
/// Alef, Waw, Yeh (the vowels the paper focuses on) plus Teh and Noon.
pub const INFIX_LETTERS: [u16; 5] = [ALEF, WAW, YEH, TEH, NOON];

/// Arabic diacritics stripped before analysis (paper §3.1): Fathatan..Sukun
/// (U+064B..U+0652) plus superscript alef.
pub const DIACRITICS: core::ops::RangeInclusive<u16> = 0x064B..=0x0652;

/// Contiguous alphabet used by the one-hot dictionary-match kernel:
/// U+0621..=U+064A (42 codepoints incl. the unused 0x063B..0x0640 gap is
/// excluded), remapped to dense indices 1..=36 with 0 = PAD.
pub const ALPHABET_SIZE: usize = 37;

/// Is `c` one of the 36 Arabic letters this system processes?
pub fn is_arabic_letter(c: u16) -> bool {
    (0x0621..=0x063A).contains(&c) || (0x0641..=0x064A).contains(&c)
}

/// Dense alphabet index for the one-hot matcher; PAD and anything
/// non-Arabic map to 0. Must match `alphabet.py::char_index`.
pub const fn char_index(c: u16) -> u8 {
    match c {
        0x0621..=0x063A => (c - 0x0621 + 1) as u8,
        0x0641..=0x064A => (c - 0x0641 + 27) as u8,
        _ => 0,
    }
}

/// Inverse of [`char_index`]. Returns PAD for 0 / out-of-range.
pub const fn index_char(i: u8) -> u16 {
    match i {
        1..=26 => 0x0621 + (i as u16 - 1),
        27..=36 => 0x0641 + (i as u16 - 27),
        _ => PAD,
    }
}

// --- Affix class bitmasks over the dense alphabet ------------------------
//
// The paper's datapath answers "is this character a prefix/suffix/infix
// letter?" with banks of parallel comparators (Figs 6–7). The software
// analog is one table load: `CHAR_CLASS[char_index(c)]` holds a bitmask of
// the classes `c` belongs to, so every class test is O(1) and branch-free
// instead of a linear scan over the letter arrays.

/// `CHAR_CLASS` bit: the character may appear in a prefix (فسألتني + the
/// normalized bare alef).
pub const CLASS_PREFIX: u8 = 1 << 0;
/// `CHAR_CLASS` bit: the character may appear in a suffix.
pub const CLASS_SUFFIX: u8 = 1 << 1;
/// `CHAR_CLASS` bit: the character may appear as an infix (أوتني).
pub const CLASS_INFIX: u8 = 1 << 2;

const fn build_char_class() -> [u8; ALPHABET_SIZE] {
    let mut table = [0u8; ALPHABET_SIZE];
    let mut i = 0;
    while i < PREFIX_LETTERS.len() {
        table[char_index(PREFIX_LETTERS[i]) as usize] |= CLASS_PREFIX;
        i += 1;
    }
    // After normalization أ has become ا, which is NOT in PREFIX_LETTERS as
    // stored (hamza form). Accept both spellings so callers can use either.
    table[char_index(ALEF) as usize] |= CLASS_PREFIX;
    let mut i = 0;
    while i < SUFFIX_LETTERS.len() {
        table[char_index(SUFFIX_LETTERS[i]) as usize] |= CLASS_SUFFIX;
        i += 1;
    }
    let mut i = 0;
    while i < INFIX_LETTERS.len() {
        table[char_index(INFIX_LETTERS[i]) as usize] |= CLASS_INFIX;
        i += 1;
    }
    table
}

/// Class bitmask per dense alphabet index (index 0 = PAD/non-Arabic, which
/// belongs to no class). The single source of truth for affix classes —
/// the letter-array constants above are retained as the human-readable
/// definition and for the paper-facing tests.
pub static CHAR_CLASS: [u8; ALPHABET_SIZE] = CHAR_CLASS_TABLE;

/// Const view of [`CHAR_CLASS`] so the bit-plane constants below can be
/// derived from it at compile time (const fns cannot read statics).
const CHAR_CLASS_TABLE: [u8; ALPHABET_SIZE] = build_char_class();

/// One affix class as a 37-bit plane over dense alphabet indices: bit `i`
/// is set iff letter `i` belongs to the class. A membership test is then
/// a shift+mask against a register-resident constant — no table load —
/// which is what the packed kernel uses per character.
const fn class_bit_plane(class: u8) -> u64 {
    let mut bits = 0u64;
    let mut i = 0;
    while i < ALPHABET_SIZE {
        if CHAR_CLASS_TABLE[i] & class != 0 {
            bits |= 1u64 << i;
        }
        i += 1;
    }
    bits
}

/// Bit plane of [`CLASS_PREFIX`] over dense indices.
pub const CLASS_PREFIX_BITS: u64 = class_bit_plane(CLASS_PREFIX);
/// Bit plane of [`CLASS_SUFFIX`] over dense indices.
pub const CLASS_SUFFIX_BITS: u64 = class_bit_plane(CLASS_SUFFIX);
/// Bit plane of [`CLASS_INFIX`] over dense indices.
pub const CLASS_INFIX_BITS: u64 = class_bit_plane(CLASS_INFIX);

/// Split a 37-bit class plane into `(lo, hi)` 32-bit halves such that
/// `lo as u64 | (hi as u64) << 32` recombines the plane — the layout the
/// SIMD lane kernel tests against in 32-bit lanes: bit `d` of the plane
/// is `((lo >> d) | (hi >> (d - 32))) & 1` under shift semantics that
/// yield 0 for any count outside `0..32` (both `vpsrlvd` and NEON `ushl`
/// behave that way, so no per-lane select is needed).
pub const fn plane_halves(plane: u64) -> (u32, u32) {
    (plane as u32, (plane >> 32) as u32)
}

/// Class bitmask of a raw codepoint (0 for PAD / non-Arabic).
#[inline]
pub fn char_class(c: u16) -> u8 {
    CHAR_CLASS[char_index(c) as usize]
}

/// Per-word affix profile: the two run lengths that make every
/// `candidate_valid(p, size)` query O(1).
///
/// Contract (shared with `ref.candidate_valid` / DESIGN.md §6): for a word
/// of length `n`,
///
/// * `prefix_run` is the largest `p ≤ min(n, MAX_PREFIX)` such that the
///   first `p` characters are all prefix letters;
/// * `suffix_start` is the smallest `k` such that characters `k..n` are all
///   suffix letters (`n` when the last character is not a suffix letter).
///
/// A cut window `[p, p+size)` then has a valid prefix iff `p ≤ prefix_run`
/// and a valid suffix iff `p + size ≥ suffix_start` — two integer
/// comparisons, replacing the per-candidate rescans of the scalar stemmer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AffixProfile {
    pub prefix_run: u8,
    pub suffix_start: u8,
}

impl AffixProfile {
    /// Compute the profile in one O(n) pass over dense indices.
    #[inline]
    pub fn from_indices(indices: &[u8]) -> AffixProfile {
        let n = indices.len();
        let max_p = MAX_PREFIX.min(n);
        let mut prefix_run = 0;
        while prefix_run < max_p
            && CHAR_CLASS[indices[prefix_run] as usize] & CLASS_PREFIX != 0
        {
            prefix_run += 1;
        }
        let mut suffix_start = n;
        while suffix_start > 0
            && CHAR_CLASS[indices[suffix_start - 1] as usize] & CLASS_SUFFIX != 0
        {
            suffix_start -= 1;
        }
        AffixProfile { prefix_run: prefix_run as u8, suffix_start: suffix_start as u8 }
    }

    /// Profile of a fixed-width word (convenience for scalar callers).
    pub fn of(w: &ArabicWord) -> AffixProfile {
        let idx = w.to_indices();
        Self::from_indices(&idx[..w.len])
    }
}

/// Normalize one codepoint the way the paper's preprocessor does:
/// hamza-carrier alefs collapse onto bare alef (`أ`/`إ`/`آ` → `ا`), alef
/// maksura collapses onto yeh, everything else is unchanged.
pub fn normalize_char(c: u16) -> u16 {
    match c {
        ALEF_MADDA | ALEF_HAMZA_ABOVE | ALEF_HAMZA_BELOW => ALEF,
        ALEF_MAKSURA => YEH,
        _ => c,
    }
}

pub fn is_diacritic(c: u16) -> bool {
    DIACRITICS.contains(&c) || c == 0x0670
}

#[inline]
pub fn is_prefix_letter(c: u16) -> bool {
    char_class(c) & CLASS_PREFIX != 0
}

#[inline]
pub fn is_suffix_letter(c: u16) -> bool {
    char_class(c) & CLASS_SUFFIX != 0
}

#[inline]
pub fn is_infix_letter(c: u16) -> bool {
    char_class(c) & CLASS_INFIX != 0
}

/// ASCII display names for the simulator traces — the paper's §5.2 display
/// code: `س` shows as "Sin" in ModelSim; we print the same names.
pub fn display_name(c: u16) -> &'static str {
    match c {
        HAMZA => "Hamza",
        ALEF_MADDA => "AlifM",
        ALEF_HAMZA_ABOVE => "AlifU",
        WAW_HAMZA => "WawH",
        ALEF_HAMZA_BELOW => "AlifL",
        YEH_HAMZA => "YaaH",
        ALEF => "Alif",
        BEH => "Baa",
        TEH_MARBUTA => "TaaM",
        TEH => "Taa",
        THEH => "Thaa",
        JEEM => "Jeem",
        HAH => "Haa",
        KHAH => "Khaa",
        DAL => "Dal",
        THAL => "Thal",
        REH => "Raa",
        ZAIN => "Zayn",
        SEEN => "Sin",
        SHEEN => "Shin",
        SAD => "Sad",
        DAD => "Dad",
        TAH => "Tah",
        ZAH => "Zah",
        AIN => "Ayn",
        GHAIN => "Ghayn",
        FEH => "Faa",
        QAF => "Qaf",
        KAF => "Kaf",
        LAM => "Lam",
        MEEM => "Mim",
        NOON => "Nun",
        HEH => "Haa2",
        WAW => "Waw",
        ALEF_MAKSURA => "YaaM",
        YEH => "Yaa",
        PAD => "U",
        _ => "?",
    }
}

/// A fixed-width (15-register) Arabic word exactly as the paper's datapath
/// holds it: left-aligned 16-bit codepoints, PAD beyond `len`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct ArabicWord {
    pub chars: [u16; MAX_WORD],
    pub len: usize,
}

impl ArabicWord {
    /// Encode a Rust string: strip diacritics and tatweel, normalize
    /// hamza-alefs, truncate at 15 characters (paper's register width).
    pub fn encode(s: &str) -> Self {
        let mut chars = [PAD; MAX_WORD];
        let mut len = 0;
        for ch in s.chars() {
            let c = ch as u32;
            if c > 0xFFFF {
                continue;
            }
            let c = c as u16;
            if is_diacritic(c) || c == 0x0640 {
                continue; // diacritics + tatweel stripped (paper §3.1)
            }
            let c = normalize_char(c);
            if len < MAX_WORD {
                chars[len] = c;
                len += 1;
            }
        }
        ArabicWord { chars, len }
    }

    /// Build from raw codepoints (already normalized).
    pub fn from_codes(codes: &[u16]) -> Self {
        let mut chars = [PAD; MAX_WORD];
        let len = codes.len().min(MAX_WORD);
        chars[..len].copy_from_slice(&codes[..len]);
        ArabicWord { chars, len }
    }

    pub fn as_slice(&self) -> &[u16] {
        &self.chars[..self.len]
    }

    /// Dense alphabet indices of the word, PAD-extended to the register
    /// width — the encoding the direct-addressed dictionaries and the SoA
    /// batch kernel operate on. Positions ≥ `len` and non-Arabic
    /// codepoints map to 0, which belongs to no affix class and can never
    /// address a stored root (all dictionary keys use indices 1..=36).
    #[inline]
    pub fn to_indices(&self) -> [u8; MAX_WORD] {
        let mut idx = [0u8; MAX_WORD];
        let mut i = 0;
        while i < self.len {
            idx[i] = char_index(self.chars[i]);
            i += 1;
        }
        idx
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Decode back into a displayable Arabic string.
    pub fn to_string_ar(&self) -> String {
        self.as_slice()
            .iter()
            .map(|&c| char::from_u32(c as u32).unwrap_or('\u{FFFD}'))
            .collect()
    }

    /// ModelSim-style display: space-separated ASCII letter names.
    pub fn to_display(&self) -> String {
        self.as_slice()
            .iter()
            .map(|&c| display_name(c))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

impl std::fmt::Debug for ArabicWord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ArabicWord({} [{}])", self.to_string_ar(), self.to_display())
    }
}

impl std::fmt::Display for ArabicWord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_string_ar())
    }
}

// --- PackedWord: the whole word in one register (PR 4) --------------------

/// Bit offset of the 4-bit length field in a [`PackedWord`].
pub const PACKED_LEN_SHIFT: u32 = (6 * MAX_WORD) as u32; // 90

/// Mask of the 90 character bits of a [`PackedWord`].
pub const PACKED_CHAR_MASK: u128 = (1u128 << PACKED_LEN_SHIFT) - 1;

/// A whole Arabic word packed into one `u128` register — the software
/// analog of the paper's fixed-width word register flowing through the
/// pipeline stages.
///
/// Layout (94 bits used, bits 94..128 always zero):
///
/// * bits `6·i .. 6·i+6` — the dense alphabet index
///   ([`char_index`], `0..ALPHABET_SIZE` ≤ 63) of character `i`
///   (character 0 in the lowest bits);
/// * bits `90..94` — the word length (`0..=MAX_WORD`).
///
/// The representation is *canonicalizing*: characters outside the
/// 36-letter alphabet pack to index 0 (PAD), exactly as the paper's
/// 16-bit datapath treats anything outside the Arabic block. They still
/// occupy a length slot, so affix-window geometry is preserved; index 0
/// belongs to no affix class and never addresses a stored root, so every
/// stemming engine produces identical results for the canonicalized and
/// the original word (the conformance proptests pin this). `unpack` is
/// therefore exact for all-Arabic words and maps non-Arabic characters
/// to PAD.
///
/// Positions `≥ len` are zero by construction, so equal words have equal
/// bit patterns — `PackedWord` equality, hashing, and the stem-cache key
/// are single `u128` comparisons.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct PackedWord(pub u128);

impl PackedWord {
    /// The empty word.
    pub const EMPTY: PackedWord = PackedWord(0);

    /// Pack a fixed-width word (one shift+or per character).
    #[inline]
    pub fn pack(w: &ArabicWord) -> PackedWord {
        let mut bits = 0u128;
        let mut i = 0;
        while i < w.len {
            bits |= (char_index(w.chars[i]) as u128) << (6 * i);
            i += 1;
        }
        PackedWord(bits | (w.len as u128) << PACKED_LEN_SHIFT)
    }

    /// Encode a Rust string straight into the register — the same
    /// strip/normalize/truncate pipeline as [`ArabicWord::encode`] with
    /// no intermediate `[u16; 15]` array. Pinned equal to
    /// `PackedWord::pack(&ArabicWord::encode(s))` by tests.
    pub fn encode(s: &str) -> PackedWord {
        let mut bits = 0u128;
        let mut len = 0usize;
        for ch in s.chars() {
            let c = ch as u32;
            if c > 0xFFFF {
                continue;
            }
            let c = c as u16;
            if is_diacritic(c) || c == 0x0640 {
                continue; // diacritics + tatweel stripped (paper §3.1)
            }
            if len == MAX_WORD {
                break;
            }
            bits |= (char_index(normalize_char(c)) as u128) << (6 * len);
            len += 1;
        }
        PackedWord(bits | (len as u128) << PACKED_LEN_SHIFT)
    }

    /// Expand back to the fixed-width codepoint form. Exact for
    /// all-Arabic words; non-Arabic characters (packed as index 0)
    /// come back as PAD — see the canonicalization note on the type.
    pub fn unpack(self) -> ArabicWord {
        let mut chars = [PAD; MAX_WORD];
        let n = self.len();
        let mut i = 0;
        while i < n {
            chars[i] = index_char(self.index_at(i));
            i += 1;
        }
        ArabicWord { chars, len: n }
    }

    /// Word length in characters (`0..=MAX_WORD`).
    #[inline]
    pub fn len(self) -> usize {
        ((self.0 >> PACKED_LEN_SHIFT) & 0xF) as usize
    }

    #[inline]
    pub fn is_empty(self) -> bool {
        self.len() == 0
    }

    /// Dense alphabet index of character `i` (0 for positions ≥ `len`).
    #[inline]
    pub fn index_at(self, i: usize) -> u8 {
        ((self.0 >> (6 * i)) & 63) as u8
    }

    /// Codepoint of character `i` (PAD for positions ≥ `len` and for
    /// canonicalized non-Arabic characters).
    #[inline]
    pub fn char_at(self, i: usize) -> u16 {
        index_char(self.index_at(i))
    }

    /// Does the word contain at least one Arabic letter? (All-zero
    /// character bits means every position is PAD/non-Arabic — the
    /// structural condition behind the wire protocol's `BAD_WORD`.)
    #[inline]
    pub fn has_arabic(self) -> bool {
        self.0 & PACKED_CHAR_MASK != 0
    }

    /// Dense-index row, PAD-extended to the register width (the SoA
    /// batch-kernel encoding).
    #[inline]
    pub fn to_indices(self) -> [u8; MAX_WORD] {
        let mut idx = [0u8; MAX_WORD];
        let mut i = 0;
        while i < MAX_WORD {
            idx[i] = self.index_at(i);
            i += 1;
        }
        idx
    }

    /// Affix profile straight off the register: each class test is one
    /// shift+mask against the `CLASS_*_BITS` planes (no table load).
    /// Agrees with [`AffixProfile::of`] on the unpacked word.
    #[inline]
    pub fn profile(self) -> AffixProfile {
        let n = self.len();
        let max_p = MAX_PREFIX.min(n);
        let mut prefix_run = 0;
        while prefix_run < max_p
            && (CLASS_PREFIX_BITS >> self.index_at(prefix_run)) & 1 != 0
        {
            prefix_run += 1;
        }
        let mut suffix_start = n;
        while suffix_start > 0
            && (CLASS_SUFFIX_BITS >> self.index_at(suffix_start - 1)) & 1 != 0
        {
            suffix_start -= 1;
        }
        AffixProfile { prefix_run: prefix_run as u8, suffix_start: suffix_start as u8 }
    }
}

impl From<&ArabicWord> for PackedWord {
    fn from(w: &ArabicWord) -> PackedWord {
        PackedWord::pack(w)
    }
}

impl From<PackedWord> for ArabicWord {
    fn from(p: PackedWord) -> ArabicWord {
        p.unpack()
    }
}

impl std::fmt::Debug for PackedWord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PackedWord({} len={} bits={:#x})", self.unpack().to_string_ar(), self.len(), self.0)
    }
}

impl std::fmt::Display for PackedWord {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.unpack().to_string_ar())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_strips_diacritics() {
        // دَرَسَ with fatha diacritics → درس
        let w = ArabicWord::encode("\u{062F}\u{064E}\u{0631}\u{064E}\u{0633}\u{064E}");
        assert_eq!(w.len, 3);
        assert_eq!(w.as_slice(), &[DAL, REH, SEEN]);
    }

    #[test]
    fn encode_normalizes_hamza_alef() {
        let w = ArabicWord::encode("\u{0623}\u{0643}\u{0644}"); // أكل
        assert_eq!(w.chars[0], ALEF);
    }

    #[test]
    fn longest_word_fits_exactly() {
        // أفاستسقيناكموها — the paper's longest word, 15 chars.
        let w = ArabicWord::encode("أفاستسقيناكموها");
        assert_eq!(w.len, 15);
    }

    #[test]
    fn char_index_roundtrip() {
        for c in 0x0621..=0x063Au16 {
            assert_eq!(index_char(char_index(c)), c);
        }
        for c in 0x0641..=0x064Au16 {
            assert_eq!(index_char(char_index(c)), c);
        }
        assert_eq!(char_index(PAD), 0);
        assert_eq!(char_index(0x0640), 0); // tatweel is not a letter
    }

    #[test]
    fn alphabet_is_dense_and_bounded() {
        let mut seen = [false; ALPHABET_SIZE];
        for c in 0x0621..=0x064Au16 {
            if is_arabic_letter(c) {
                let i = char_index(c) as usize;
                assert!(i > 0 && i < ALPHABET_SIZE);
                assert!(!seen[i], "collision at {c:04X}");
                seen[i] = true;
            }
        }
        assert_eq!(seen.iter().filter(|&&b| b).count(), 36);
    }

    #[test]
    fn prefix_letters_match_paper_vhdl() {
        // Fig. 3 VHDL constant: x0623 x062A x0633 x0641 x0644 x0646 x064A
        let mut p = PREFIX_LETTERS;
        p.sort();
        assert_eq!(p, [0x0623, 0x062A, 0x0633, 0x0641, 0x0644, 0x0646, 0x064A]);
    }

    #[test]
    fn display_names() {
        assert_eq!(display_name(SEEN), "Sin");
        assert_eq!(display_name(PAD), "U");
    }

    /// The class table must agree with the letter arrays it was compiled
    /// from, for every 16-bit codepoint (incl. PAD and non-Arabic).
    #[test]
    fn char_class_table_matches_letter_arrays() {
        for c in 0u16..=0x0700 {
            let want_prefix = PREFIX_LETTERS.contains(&c) || c == ALEF;
            let want_suffix = SUFFIX_LETTERS.contains(&c);
            let want_infix = INFIX_LETTERS.contains(&c);
            assert_eq!(is_prefix_letter(c), want_prefix, "prefix class of {c:04X}");
            assert_eq!(is_suffix_letter(c), want_suffix, "suffix class of {c:04X}");
            assert_eq!(is_infix_letter(c), want_infix, "infix class of {c:04X}");
        }
        assert_eq!(CHAR_CLASS[0], 0, "PAD must belong to no class");
    }

    /// The class bit planes agree entry-for-entry with the class table.
    #[test]
    fn class_bit_planes_match_table() {
        for i in 0..ALPHABET_SIZE {
            assert_eq!(
                (CLASS_PREFIX_BITS >> i) & 1 != 0,
                CHAR_CLASS[i] & CLASS_PREFIX != 0,
                "prefix plane at {i}"
            );
            assert_eq!(
                (CLASS_SUFFIX_BITS >> i) & 1 != 0,
                CHAR_CLASS[i] & CLASS_SUFFIX != 0,
                "suffix plane at {i}"
            );
            assert_eq!(
                (CLASS_INFIX_BITS >> i) & 1 != 0,
                CHAR_CLASS[i] & CLASS_INFIX != 0,
                "infix plane at {i}"
            );
        }
        // no plane bits beyond the alphabet
        assert_eq!(CLASS_PREFIX_BITS >> ALPHABET_SIZE, 0);
        assert_eq!(CLASS_SUFFIX_BITS >> ALPHABET_SIZE, 0);
        assert_eq!(CLASS_INFIX_BITS >> ALPHABET_SIZE, 0);
    }

    /// The 32-bit plane halves recombine to the u64 plane bit-exactly
    /// (the SIMD lane kernel's view of the comparator banks).
    #[test]
    fn plane_halves_recombine() {
        for plane in [CLASS_PREFIX_BITS, CLASS_SUFFIX_BITS, CLASS_INFIX_BITS, 0, u64::MAX] {
            let (lo, hi) = plane_halves(plane);
            assert_eq!(lo as u64 | (hi as u64) << 32, plane, "plane {plane:#x}");
        }
    }

    #[test]
    fn packed_layout_and_length() {
        let w = ArabicWord::encode("درس");
        let p = PackedWord::pack(&w);
        assert_eq!(p.len(), 3);
        assert_eq!(p.index_at(0), char_index(DAL));
        assert_eq!(p.index_at(1), char_index(REH));
        assert_eq!(p.index_at(2), char_index(SEEN));
        assert_eq!(p.index_at(3), 0, "past-len positions are zero");
        // bit-exact layout: 6 bits per char, length nibble at bit 90
        let want = (char_index(DAL) as u128)
            | (char_index(REH) as u128) << 6
            | (char_index(SEEN) as u128) << 12
            | 3u128 << PACKED_LEN_SHIFT;
        assert_eq!(p.0, want);
        assert!(p.0 >> 94 == 0, "bits 94..128 must stay zero");
        assert_eq!(PackedWord::EMPTY.len(), 0);
        assert!(PackedWord::EMPTY.is_empty());
        assert!(!PackedWord::EMPTY.has_arabic());
    }

    /// pack/unpack is an exact roundtrip on all-Arabic words, including
    /// the 15-character maximum; packing is canonical (equal words ⇒
    /// equal bits, via the zero tail).
    #[test]
    fn packed_roundtrip_arabic() {
        for s in ["", "درس", "سيلعبون", "أفاستسقيناكموها", "فتزحزحت", "ظظظظ"] {
            let w = ArabicWord::encode(s);
            let p = PackedWord::pack(&w);
            assert_eq!(p.unpack(), w, "roundtrip of {s:?}");
            assert_eq!(PackedWord::pack(&p.unpack()), p, "repack of {s:?}");
            assert_eq!(p.to_indices(), w.to_indices(), "indices of {s:?}");
        }
    }

    /// Direct string encoding matches encode-then-pack, for Arabic,
    /// diacritic-laden, mixed, oversized, and non-Arabic input.
    #[test]
    fn packed_encode_matches_array_encode() {
        for s in [
            "",
            "درس",
            "\u{062F}\u{064E}\u{0631}\u{064E}\u{0633}\u{064E}", // with fatha
            "أفاستسقيناكموها",
            "أفاستسقيناكموهاوووو", // truncates at 15
            "hello",
            "قاxل",
            "😀درس",
            "  ",
        ] {
            assert_eq!(
                PackedWord::encode(s),
                PackedWord::pack(&ArabicWord::encode(s)),
                "encode of {s:?}"
            );
        }
    }

    /// Non-Arabic characters canonicalize to PAD but keep their length
    /// slot, so window geometry survives; `has_arabic` sees through it.
    #[test]
    fn packed_canonicalizes_non_arabic() {
        let p = PackedWord::encode("hello");
        assert_eq!(p.len(), 5);
        assert!(!p.has_arabic());
        assert_eq!(p.unpack().as_slice(), &[PAD; 5]);
        let mixed = PackedWord::encode("قاxل");
        assert_eq!(mixed.len(), 4);
        assert!(mixed.has_arabic());
        assert_eq!(mixed.index_at(2), 0);
        assert_eq!(mixed.char_at(0), QAF);
    }

    /// The register-resident profile equals the table-driven profile of
    /// the unpacked word on a sweep of shapes.
    #[test]
    fn packed_profile_matches_affix_profile() {
        let words =
            ["سيلعبون", "أفاستسقيناكموها", "بكتبون", "درس", "", "ظظظظ", "ستون", "hello"];
        for s in words {
            let w = ArabicWord::encode(s);
            let p = PackedWord::pack(&w);
            assert_eq!(p.profile(), AffixProfile::of(&w), "profile of {s:?}");
        }
    }

    #[test]
    fn to_indices_matches_char_index() {
        let w = ArabicWord::encode("سيلعبون");
        let idx = w.to_indices();
        for i in 0..MAX_WORD {
            let want = if i < w.len { char_index(w.chars[i]) } else { 0 };
            assert_eq!(idx[i], want, "position {i}");
        }
    }

    /// AffixProfile vs the naive per-cut rescans it replaces.
    #[test]
    fn affix_profile_matches_naive_scans() {
        let words = [
            "سيلعبون",
            "أفاستسقيناكموها",
            "بكتبون",
            "درس",
            "",
            "ظظظظ",
            "ستون",  // all prefix/suffix letters
            "hello", // non-Arabic survives encode; classless
        ];
        for s in words {
            let w = ArabicWord::encode(s);
            let prof = AffixProfile::of(&w);
            let max_p = MAX_PREFIX.min(w.len);
            let mut want_run = 0;
            while want_run < max_p && is_prefix_letter(w.chars[want_run]) {
                want_run += 1;
            }
            assert_eq!(prof.prefix_run as usize, want_run, "prefix run of {s:?}");
            let mut want_start = w.len;
            while want_start > 0 && is_suffix_letter(w.chars[want_start - 1]) {
                want_start -= 1;
            }
            assert_eq!(prof.suffix_start as usize, want_start, "suffix start of {s:?}");
            // the O(1) candidate queries agree with the rescans
            for p in 0..=MAX_PREFIX.min(w.len) {
                let prefix_ok = w.chars[..p].iter().all(|&c| is_prefix_letter(c));
                assert_eq!(p <= prof.prefix_run as usize, prefix_ok, "{s:?} p={p}");
                for size in [3usize, 4] {
                    if p + size > w.len {
                        continue;
                    }
                    let suffix_ok =
                        w.chars[p + size..w.len].iter().all(|&c| is_suffix_letter(c));
                    assert_eq!(
                        p + size >= prof.suffix_start as usize,
                        suffix_ok,
                        "{s:?} p={p} size={size}"
                    );
                }
            }
        }
    }
}
