//! The software LB stemmer — the paper's Java baseline, ported and then
//! rebuilt table-driven for throughput.
//!
//! Semantics are the shared contract of DESIGN.md §6 and must agree
//! bit-for-bit with `python/compile/kernels/ref.py::ref_stem_word`, the JAX
//! model, and the HW simulator (cross-validation tests enforce this).
//!
//! Two implementations coexist:
//!
//! * [`Stemmer::stem_reference`] — the original scalar port: per-candidate
//!   prefix/suffix rescans and SipHash `HashSet` probes. Kept as the
//!   executable specification and the benchmark baseline.
//! * [`Stemmer::stem`] — the fused hot path, mirroring the paper's
//!   hardware: affix classes come from the [`chars::CHAR_CLASS`] bitmask
//!   table (the comparator banks of Figs 6–7), per-word validity from one
//!   O(n) [`AffixProfile`] (the prd-masks of §4.1), and dictionary
//!   membership from the direct-addressed [`crate::roots::RootBitmap`]s
//!   (the block-RAM comparator stage). One pass over the six cut
//!   positions evaluates all five candidate streams; a property test
//!   (`proptests::prop_optimized_stem_matches_reference`) pins the two
//!   paths together on tens of thousands of inflected words.
//!
//! [`Stemmer::stem_batch`] runs the same kernel over a structure-of-arrays
//! batch encoding (contiguous dense-index rows + lengths + profiles), and
//! [`Stemmer::stem_batch_parallel`] fans chunks of that encoding out
//! across an [`crate::exec::WorkerPool`].
//!
//! PR 4 adds the *packed* pair: [`Stemmer::stem_packed`] /
//! [`Stemmer::stem_batch_packed`] run the fused kernel directly on
//! [`chars::PackedWord`] registers (6 bits/char in one `u128`) — affix
//! classes by shift+mask against the `CLASS_*_BITS` planes, dictionary
//! keys accumulated from the packed nibbles. The array kernel is retained
//! as the packed kernel's benchmark baseline, exactly as `stem_reference`
//! is the array kernel's.
//!
//! PR 6 adds the lane dimension: [`Stemmer::stem_batch_packed`] is now a
//! *dispatcher* — wide batches go through the [`crate::simd`] lane-group
//! kernel ([`Stemmer::stem_batch_simd`]) when a path is active, narrow
//! batches and the `AMA_SIMD=off` escape hatch run
//! [`Stemmer::stem_batch_packed_scalar`], the pinned per-word kernel
//! retained as the SIMD baseline (the same baseline role `stem` plays
//! for `stem_packed`).

use crate::chars::{self, AffixProfile, ArabicWord, PackedWord, MAX_PREFIX, MAX_SUFFIX, MAX_WORD};
use crate::exec::{BoundedQueue, WorkerPool};
use crate::roots::RootSet;
use crate::chk::sync::atomic::{AtomicUsize, Ordering};
use crate::chk::sync::Arc;

/// How a root was found — mirrors `alphabet.py::KIND_*`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum MatchKind {
    /// No root extracted.
    None = 0,
    /// Direct trilateral dictionary match.
    Tri = 1,
    /// Direct quadrilateral dictionary match.
    Quad = 2,
    /// *Remove Infix* (Fig 18): quad stem, infix 2nd char dropped → trilateral.
    RmInfixTri = 3,
    /// *Remove Infix*: tri stem, infix 2nd char dropped → bilateral.
    RmInfixBi = 4,
    /// *Restore Original Form* (Fig 19): hollow verb, 2nd char ا→و → trilateral.
    Restored = 5,
}

impl MatchKind {
    pub fn from_u8(v: u8) -> MatchKind {
        match v {
            1 => MatchKind::Tri,
            2 => MatchKind::Quad,
            3 => MatchKind::RmInfixTri,
            4 => MatchKind::RmInfixBi,
            5 => MatchKind::Restored,
            _ => MatchKind::None,
        }
    }

    /// Did this extraction use one of the §6.3 infix algorithms?
    pub fn used_infix(self) -> bool {
        matches!(self, MatchKind::RmInfixTri | MatchKind::RmInfixBi | MatchKind::Restored)
    }
}

/// Result of root extraction for one word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StemResult {
    /// The extracted root, 0-padded to 4 characters.
    pub root: [u16; 4],
    pub kind: MatchKind,
    /// The winning prefix cut index `p` (0..=5).
    pub cut: u8,
}

impl StemResult {
    pub const NONE: StemResult = StemResult { root: [0; 4], kind: MatchKind::None, cut: 0 };

    pub fn root_len(&self) -> usize {
        self.root.iter().take_while(|&&c| c != 0).count()
    }

    pub fn root_word(&self) -> ArabicWord {
        ArabicWord::from_codes(&self.root[..self.root_len()])
    }
}

/// Configuration for the stemmer (Table 6 compares infix on/off).
#[derive(Clone, Copy, Debug)]
pub struct StemmerConfig {
    /// Enable the two §6.3 infix algorithms (Remove Infix, Restore Form).
    pub infix_processing: bool,
}

impl Default for StemmerConfig {
    fn default() -> Self {
        StemmerConfig { infix_processing: true }
    }
}

/// Structure-of-arrays encoding of a word batch: contiguous dense-index
/// rows (`MAX_WORD` bytes per word), lengths, and per-word affix profiles.
/// Encoded once per batch so the stemming loop touches only flat, cache-
/// friendly buffers — the software analog of the paper's fixed-width
/// register file feeding the datapath.
pub struct SoaBatch {
    /// Row-major `words.len() × MAX_WORD` dense alphabet indices.
    pub indices: Vec<u8>,
    /// Word lengths (≤ `MAX_WORD`).
    pub lens: Vec<u8>,
    /// Per-word affix profiles.
    pub profiles: Vec<AffixProfile>,
}

impl SoaBatch {
    pub fn encode(words: &[ArabicWord]) -> SoaBatch {
        let mut indices = vec![0u8; words.len() * MAX_WORD];
        let mut lens = Vec::with_capacity(words.len());
        let mut profiles = Vec::with_capacity(words.len());
        for (i, w) in words.iter().enumerate() {
            let row = &mut indices[i * MAX_WORD..(i + 1) * MAX_WORD];
            row.copy_from_slice(&w.to_indices());
            lens.push(w.len as u8);
            profiles.push(AffixProfile::from_indices(&row[..w.len]));
        }
        SoaBatch { indices, lens, profiles }
    }

    /// Dense-index row of word `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[u8] {
        &self.indices[i * MAX_WORD..(i + 1) * MAX_WORD]
    }
}

/// Minimum chunk width of the parallel batch kernel: big enough to
/// amortize scheduling, small enough that coordinator-sized batches
/// (hundreds of words) still fan out across several workers.
const MIN_PARALLEL_CHUNK: usize = 256;

/// How long the assembler waits for one chunk before concluding a worker
/// died (stemming a chunk takes microseconds; this is pure deadlock
/// insurance).
const CHUNK_DEADLINE: std::time::Duration = std::time::Duration::from_secs(30);

const IDX_ALEF: u8 = chars::char_index(chars::ALEF);
const IDX_WAW: u8 = chars::char_index(chars::WAW);
const A: usize = chars::ALPHABET_SIZE;

/// Sentinel for "stream found no cut".
const NO_CUT: usize = usize::MAX;

/// Chunk width of the parallel batch kernel: every worker gets ~4 chunks
/// for load balance, never below the amortization floor, and always a
/// multiple of [`crate::simd::LANES`] so the parallel fan-out never
/// strands remainder-lane (scalar-path) work on interior chunk
/// boundaries — only the final chunk of the whole batch may be ragged.
pub(crate) fn parallel_chunk_size(len: usize, workers: usize) -> usize {
    len.div_ceil(workers * 4)
        .max(MIN_PARALLEL_CHUNK)
        .next_multiple_of(crate::simd::LANES)
}

/// The linguistic-based stemmer.
pub struct Stemmer {
    roots: Arc<RootSet>,
    config: StemmerConfig,
}

impl Stemmer {
    pub fn new(roots: Arc<RootSet>, config: StemmerConfig) -> Self {
        Stemmer { roots, config }
    }

    pub fn with_defaults(roots: Arc<RootSet>) -> Self {
        Self::new(roots, StemmerConfig::default())
    }

    pub fn roots(&self) -> &RootSet {
        &self.roots
    }

    pub fn config(&self) -> StemmerConfig {
        self.config
    }

    /// A stemmer over the same (shared) dictionaries with a different
    /// infix setting — how the unified `Analyzer` API honors a
    /// per-request infix override without rebuilding any tables.
    pub fn with_infix(&self, infix: bool) -> Stemmer {
        Stemmer::new(self.roots.clone(), StemmerConfig { infix_processing: infix })
    }

    /// Is the window `word[p..p+size]` a valid stem candidate?
    /// (DESIGN.md §6 shared contract — `ref.candidate_valid`.) Used by the
    /// reference path; the fused path answers this from the AffixProfile.
    fn candidate_valid(w: &ArabicWord, p: usize, size: usize) -> bool {
        let n = w.len;
        if p + size > n || n - (p + size) > MAX_SUFFIX {
            return false;
        }
        if !w.chars[..p].iter().all(|&c| chars::is_prefix_letter(c)) {
            return false;
        }
        w.chars[p + size..n].iter().all(|&c| chars::is_suffix_letter(c))
    }

    /// Extract the verb root of `w`. Priority: direct tri, direct quad,
    /// remove-infix tri, remove-infix bi, restored form; smaller cut first.
    ///
    /// This is the fused hot path: one pass over the cut positions with
    /// O(1) candidate validity (two comparisons against the profile) and
    /// O(1) bitset membership per stream. Must stay bit-for-bit equal to
    /// [`Self::stem_reference`].
    pub fn stem(&self, w: &ArabicWord) -> StemResult {
        let idx = w.to_indices();
        let profile = AffixProfile::from_indices(&idx[..w.len]);
        self.stem_encoded(w, &idx, profile)
    }

    /// The fused kernel over a pre-encoded word. `idx` must hold the
    /// dense indices of `w` (PAD-extended to at least `MAX_WORD` entries)
    /// and `profile` its affix profile.
    fn stem_encoded(&self, w: &ArabicWord, idx: &[u8], profile: AffixProfile) -> StemResult {
        debug_assert!(idx.len() >= MAX_WORD);
        let n = w.len;
        let dicts = &self.roots.dense;
        let infix = self.config.infix_processing;
        let suffix_start = profile.suffix_start as usize;

        // First-hit cuts for the lower-priority streams (priority is
        // kind-major, then smallest cut — pass order in ref_stem_word).
        // The trilateral stream short-circuits: it is the highest priority
        // and cuts ascend, so its first hit is the final answer.
        let mut quad_cut = NO_CUT;
        let mut rm3_cut = NO_CUT;
        let mut rm2_cut = NO_CUT;
        let mut rs3_cut = NO_CUT;

        // p ≤ prefix_run ⇔ the first p characters are all prefix letters;
        // prefix_run ≤ min(n, MAX_PREFIX) by construction.
        for p in 0..=profile.prefix_run as usize {
            // Window validity beyond the prefix check: fits the word, the
            // tail is short enough, and the tail is all suffix letters.
            let e3 = p + 3;
            let ok3 = e3 <= n && n - e3 <= MAX_SUFFIX && e3 >= suffix_start;
            let e4 = p + 4;
            let ok4 = e4 <= n && n - e4 <= MAX_SUFFIX && e4 >= suffix_start;
            if ok3 {
                let key3 = ((idx[p] as usize * A) + idx[p + 1] as usize) * A
                    + idx[p + 2] as usize;
                if dicts.tri.contains_key(key3) {
                    return StemResult {
                        root: [w.chars[p], w.chars[p + 1], w.chars[p + 2], 0],
                        kind: MatchKind::Tri,
                        cut: p as u8,
                    };
                }
            }
            if ok4 && quad_cut == NO_CUT {
                let key4 = (((idx[p] as usize * A) + idx[p + 1] as usize) * A
                    + idx[p + 2] as usize)
                    * A
                    + idx[p + 3] as usize;
                if dicts.quad.contains_key(key4) {
                    quad_cut = p;
                }
            }
            if infix {
                let second = idx[p + 1] as usize;
                let second_infix = chars::CHAR_CLASS[second] & chars::CLASS_INFIX != 0;
                if ok4 && rm3_cut == NO_CUT && second_infix {
                    let key = ((idx[p] as usize * A) + idx[p + 2] as usize) * A
                        + idx[p + 3] as usize;
                    if dicts.tri.contains_key(key) {
                        rm3_cut = p;
                    }
                }
                if ok3 && rm2_cut == NO_CUT && second_infix {
                    let key = idx[p] as usize * A + idx[p + 2] as usize;
                    if dicts.bi.contains_key(key) {
                        rm2_cut = p;
                    }
                }
                if ok3 && rs3_cut == NO_CUT && idx[p + 1] == IDX_ALEF {
                    let key = ((idx[p] as usize * A) + IDX_WAW as usize) * A
                        + idx[p + 2] as usize;
                    if dicts.tri.contains_key(key) {
                        rs3_cut = p;
                    }
                }
            }
        }

        if quad_cut != NO_CUT {
            let p = quad_cut;
            return StemResult {
                root: [w.chars[p], w.chars[p + 1], w.chars[p + 2], w.chars[p + 3]],
                kind: MatchKind::Quad,
                cut: p as u8,
            };
        }
        if rm3_cut != NO_CUT {
            let p = rm3_cut;
            return StemResult {
                root: [w.chars[p], w.chars[p + 2], w.chars[p + 3], 0],
                kind: MatchKind::RmInfixTri,
                cut: p as u8,
            };
        }
        if rm2_cut != NO_CUT {
            let p = rm2_cut;
            return StemResult {
                root: [w.chars[p], w.chars[p + 2], 0, 0],
                kind: MatchKind::RmInfixBi,
                cut: p as u8,
            };
        }
        if rs3_cut != NO_CUT {
            let p = rs3_cut;
            return StemResult {
                root: [w.chars[p], chars::WAW, w.chars[p + 2], 0],
                kind: MatchKind::Restored,
                cut: p as u8,
            };
        }
        StemResult::NONE
    }

    /// The packed fused kernel (PR 4): the whole word stays in one
    /// `u128` register end to end. Per-character affix classes are
    /// shift+mask probes against the [`chars::CLASS_INFIX_BITS`]-style
    /// bit planes; the direct tri/quad streams probe the dictionaries
    /// through [`crate::roots::RootBitmap::contains_packed`] while the
    /// modified-window streams (remove-infix, restore) accumulate their
    /// base-37 keys from the packed nibbles inline; root codepoints are
    /// reconstructed through [`chars::index_char`] only for the one
    /// winning window.
    ///
    /// Bit-identical to [`Self::stem`] on the packed view of any word:
    /// a returned root's characters are always dictionary letters, for
    /// which `index_char ∘ char_index` is the identity, and index 0
    /// (canonicalized non-Arabic) belongs to no class and no dictionary —
    /// so `stem_packed(pack(w)) == stem(w)` for *every* `w`, canonical
    /// or not (the proptests pin this).
    pub fn stem_packed(&self, w: PackedWord) -> StemResult {
        self.stem_packed_profiled(w, w.profile())
    }

    /// The packed kernel over a precomputed profile (the batch loop's
    /// entry point).
    fn stem_packed_profiled(&self, w: PackedWord, profile: AffixProfile) -> StemResult {
        let n = w.len();
        let word = w.0;
        let nib = |i: usize| ((word >> (6 * i)) & 63) as usize;
        let dicts = &self.roots.dense;
        let infix = self.config.infix_processing;
        let suffix_start = profile.suffix_start as usize;

        let mut quad_cut = NO_CUT;
        let mut rm3_cut = NO_CUT;
        let mut rm2_cut = NO_CUT;
        let mut rs3_cut = NO_CUT;

        for p in 0..=profile.prefix_run as usize {
            let e3 = p + 3;
            let ok3 = e3 <= n && n - e3 <= MAX_SUFFIX && e3 >= suffix_start;
            let e4 = p + 4;
            let ok4 = e4 <= n && n - e4 <= MAX_SUFFIX && e4 >= suffix_start;
            if ok3 {
                if dicts.tri.contains_packed(w, p) {
                    return StemResult {
                        root: [
                            chars::index_char(nib(p) as u8),
                            chars::index_char(nib(p + 1) as u8),
                            chars::index_char(nib(p + 2) as u8),
                            0,
                        ],
                        kind: MatchKind::Tri,
                        cut: p as u8,
                    };
                }
            }
            if ok4 && quad_cut == NO_CUT && dicts.quad.contains_packed(w, p) {
                quad_cut = p;
            }
            if infix {
                let second = nib(p + 1);
                let second_infix = (chars::CLASS_INFIX_BITS >> second) & 1 != 0;
                // The remove-infix / restore streams probe *modified*
                // windows (a nibble skipped or substituted), so their
                // keys are accumulated inline with the same base-37
                // scheme as `RootBitmap::key_packed`.
                if ok4 && rm3_cut == NO_CUT && second_infix {
                    let key = ((nib(p) * A) + nib(p + 2)) * A + nib(p + 3);
                    if dicts.tri.contains_key(key) {
                        rm3_cut = p;
                    }
                }
                if ok3 && rm2_cut == NO_CUT && second_infix {
                    let key = nib(p) * A + nib(p + 2);
                    if dicts.bi.contains_key(key) {
                        rm2_cut = p;
                    }
                }
                if ok3 && rs3_cut == NO_CUT && second == IDX_ALEF as usize {
                    let key = ((nib(p) * A) + IDX_WAW as usize) * A + nib(p + 2);
                    if dicts.tri.contains_key(key) {
                        rs3_cut = p;
                    }
                }
            }
        }

        if quad_cut != NO_CUT {
            let p = quad_cut;
            return StemResult {
                root: [
                    chars::index_char(nib(p) as u8),
                    chars::index_char(nib(p + 1) as u8),
                    chars::index_char(nib(p + 2) as u8),
                    chars::index_char(nib(p + 3) as u8),
                ],
                kind: MatchKind::Quad,
                cut: p as u8,
            };
        }
        if rm3_cut != NO_CUT {
            let p = rm3_cut;
            return StemResult {
                root: [
                    chars::index_char(nib(p) as u8),
                    chars::index_char(nib(p + 2) as u8),
                    chars::index_char(nib(p + 3) as u8),
                    0,
                ],
                kind: MatchKind::RmInfixTri,
                cut: p as u8,
            };
        }
        if rm2_cut != NO_CUT {
            let p = rm2_cut;
            return StemResult {
                root: [
                    chars::index_char(nib(p) as u8),
                    chars::index_char(nib(p + 2) as u8),
                    0,
                    0,
                ],
                kind: MatchKind::RmInfixBi,
                cut: p as u8,
            };
        }
        if rs3_cut != NO_CUT {
            let p = rs3_cut;
            return StemResult {
                root: [
                    chars::index_char(nib(p) as u8),
                    chars::WAW,
                    chars::index_char(nib(p + 2) as u8),
                    0,
                ],
                kind: MatchKind::Restored,
                cut: p as u8,
            };
        }
        StemResult::NONE
    }

    /// Packed batch kernel: the `Vec<PackedWord>` *is* the
    /// structure-of-arrays encoding — 16 contiguous bytes per word, no
    /// index rows, lengths, or profile side arrays to build. This is the
    /// form the coordinator's request queue and the server's line ingest
    /// feed directly.
    ///
    /// Since PR 6 this is the dispatch point: batches of at least
    /// [`simd::MIN_SIMD_BATCH`] words run the [`crate::simd`] lane-group
    /// kernel on the [`simd::active`] path (AVX2 / NEON / portable);
    /// narrow batches — and every batch under `AMA_SIMD=off` — run the
    /// pinned scalar kernel. Both are bit-identical (proptest-pinned),
    /// so callers ([`crate::analysis`], the coordinator, serving) see
    /// only the throughput change.
    ///
    /// [`simd::MIN_SIMD_BATCH`]: crate::simd::MIN_SIMD_BATCH
    /// [`simd::active`]: crate::simd::active
    pub fn stem_batch_packed(&self, words: &[PackedWord]) -> Vec<StemResult> {
        if words.len() >= crate::simd::MIN_SIMD_BATCH {
            if let Some(path) = crate::simd::active() {
                return crate::simd::stem_batch_simd_with(self, words, path);
            }
        }
        self.stem_batch_packed_scalar(words)
    }

    /// The per-word packed batch kernel, pinned as the lane kernel's
    /// byte-identical baseline (benchmarked as
    /// `software/stem_batch_packed`; the conformance tests and the
    /// python oracle sweep compare every SIMD path against it).
    pub fn stem_batch_packed_scalar(&self, words: &[PackedWord]) -> Vec<StemResult> {
        words.iter().map(|&w| self.stem_packed_profiled(w, w.profile())).collect()
    }

    /// The lane-group batch kernel (PR 6), unconditionally — on the
    /// process-wide [`crate::simd::active`] path, or the best available
    /// path when dispatch is disabled. This is the `software/
    /// stem_batch_simd` bench row; production callers should prefer
    /// [`Self::stem_batch_packed`], which also handles narrow batches.
    pub fn stem_batch_simd(&self, words: &[PackedWord]) -> Vec<StemResult> {
        let path = crate::simd::active().unwrap_or_else(crate::simd::best_available);
        crate::simd::stem_batch_simd_with(self, words, path)
    }

    /// The original scalar implementation — per-candidate rescans and
    /// `HashSet` probes. Retained as the executable specification and the
    /// benchmark baseline for [`Self::stem`]; do not optimize.
    pub fn stem_reference(&self, w: &ArabicWord) -> StemResult {
        // Passes 1–2: direct trilateral then quadrilateral.
        for p in 0..MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 3) {
                let stem = [w.chars[p], w.chars[p + 1], w.chars[p + 2]];
                if self.roots.tri.contains(&stem) {
                    return StemResult {
                        root: [stem[0], stem[1], stem[2], 0],
                        kind: MatchKind::Tri,
                        cut: p as u8,
                    };
                }
            }
        }
        for p in 0..MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 4) {
                let stem = [w.chars[p], w.chars[p + 1], w.chars[p + 2], w.chars[p + 3]];
                if self.roots.quad.contains(&stem) {
                    return StemResult { root: stem, kind: MatchKind::Quad, cut: p as u8 };
                }
            }
        }
        if !self.config.infix_processing {
            return StemResult::NONE;
        }
        // Pass 3: Remove Infix on quadrilateral stems → trilateral roots.
        for p in 0..MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 4) && chars::is_infix_letter(w.chars[p + 1]) {
                let red = [w.chars[p], w.chars[p + 2], w.chars[p + 3]];
                if self.roots.tri.contains(&red) {
                    return StemResult {
                        root: [red[0], red[1], red[2], 0],
                        kind: MatchKind::RmInfixTri,
                        cut: p as u8,
                    };
                }
            }
        }
        // Pass 4: Remove Infix on trilateral stems → bilateral roots.
        for p in 0..MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 3) && chars::is_infix_letter(w.chars[p + 1]) {
                let red = [w.chars[p], w.chars[p + 2]];
                if self.roots.bi.contains(&red) {
                    return StemResult {
                        root: [red[0], red[1], 0, 0],
                        kind: MatchKind::RmInfixBi,
                        cut: p as u8,
                    };
                }
            }
        }
        // Pass 5: Restore Original Form (hollow verbs): 2nd char ا → و.
        for p in 0..MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 3) && w.chars[p + 1] == chars::ALEF {
                let res = [w.chars[p], chars::WAW, w.chars[p + 2]];
                if self.roots.tri.contains(&res) {
                    return StemResult {
                        root: [res[0], res[1], res[2], 0],
                        kind: MatchKind::Restored,
                        cut: p as u8,
                    };
                }
            }
        }
        StemResult::NONE
    }

    /// Stem a batch through the SoA kernel: encode once into contiguous
    /// index/length/profile buffers, then run the fused kernel per row.
    ///
    /// Wide batches pack into `u128` registers and dispatch to the
    /// lane-group kernel instead (PR 6) — semantics-preserving because
    /// `stem_packed(pack(w)) == stem(w)` for every word (proptest-pinned
    /// since PR 4) and the lane kernel equals `stem_packed` lane-wise.
    pub fn stem_batch(&self, words: &[ArabicWord]) -> Vec<StemResult> {
        if words.len() >= crate::simd::MIN_SIMD_BATCH {
            if let Some(path) = crate::simd::active() {
                let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();
                return crate::simd::stem_batch_simd_with(self, &packed, path);
            }
        }
        let batch = SoaBatch::encode(words);
        words
            .iter()
            .enumerate()
            .map(|(i, w)| self.stem_encoded(w, batch.row(i), batch.profiles[i]))
            .collect()
    }

    /// Parallel batch kernel: chunks claimed from an atomic cursor by an
    /// [`exec::WorkerPool`], results reassembled in order. Falls back to
    /// the sequential kernel for batches too small to amortize the
    /// per-call thread spawn (the pool is not persistent — the big wins
    /// are bulk workloads: benches, corpus analysis, `--batch ≥ 4096`
    /// serving).
    ///
    /// [`exec::WorkerPool`]: crate::exec::WorkerPool
    pub fn stem_batch_parallel(&self, words: &[ArabicWord], workers: usize) -> Vec<StemResult> {
        if workers <= 1 || words.len() < 2 * MIN_PARALLEL_CHUNK {
            return self.stem_batch(words);
        }
        let chunk = parallel_chunk_size(words.len(), workers);
        let n_chunks = words.len().div_ceil(chunk);
        let shared: Arc<Vec<ArabicWord>> = Arc::new(words.to_vec());
        let cursor = Arc::new(AtomicUsize::new(0));
        // Capacity = n_chunks so producers never block; exactly n_chunks
        // results arrive.
        let done: Arc<BoundedQueue<(usize, Vec<StemResult>)>> = BoundedQueue::new(n_chunks);
        let roots = self.roots.clone();
        let config = self.config;
        let pool = WorkerPool::spawn(workers.min(n_chunks), "stem-batch", {
            let shared = shared.clone();
            let cursor = cursor.clone();
            let done = done.clone();
            move |_id, _shutdown| {
                let stemmer = Stemmer::new(roots.clone(), config);
                loop {
                    // ord: Relaxed — work-stealing cursor; only the RMW's
                    // atomicity matters, chunk results flow through the queue.
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= shared.len() {
                        break;
                    }
                    let end = (start + chunk).min(shared.len());
                    let res = stemmer.stem_batch(&shared[start..end]);
                    if done.push((start, res)).is_err() {
                        break;
                    }
                }
            }
        });
        let mut out = vec![StemResult::NONE; words.len()];
        let mut failure = None;
        for _ in 0..n_chunks {
            // The timeout is deadlock insurance: if a worker panics before
            // delivering its claimed chunk, fail loudly instead of blocking
            // forever on a queue nobody will ever fill.
            match done.pop_timeout(CHUNK_DEADLINE) {
                Ok((start, res)) => out[start..start + res.len()].copy_from_slice(&res),
                Err(e) => {
                    failure = Some(e);
                    break;
                }
            }
        }
        done.close();
        pool.join();
        if let Some(e) = failure {
            panic!("stem_batch_parallel: worker died without delivering a chunk ({e:?})");
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use std::sync::Arc;

    fn stemmer() -> Stemmer {
        Stemmer::with_defaults(Arc::new(RootSet::builtin_mini()))
    }

    fn root_str(r: &StemResult) -> String {
        r.root_word().to_string_ar()
    }

    #[test]
    fn paper_example_silabun() {
        // سيلعبون → لعب (paper §3.1, Table 3)
        let r = stemmer().stem(&ArabicWord::encode("سيلعبون"));
        assert_eq!(root_str(&r), "لعب");
        assert_eq!(r.kind, MatchKind::Tri);
        assert_eq!(r.cut, 2);
    }

    #[test]
    fn paper_example_longest_word() {
        // أفاستسقيناكموها → سقي (paper §3.1, Fig 13)
        let r = stemmer().stem(&ArabicWord::encode("أفاستسقيناكموها"));
        assert_eq!(root_str(&r), "سقي");
        assert_eq!(r.kind, MatchKind::Tri);
    }

    #[test]
    fn paper_example_quadrilateral() {
        // فتزحزحت → زحزح (paper Fig 14)
        let r = stemmer().stem(&ArabicWord::encode("فتزحزحت"));
        assert_eq!(root_str(&r), "زحزح");
        assert_eq!(r.kind, MatchKind::Quad);
    }

    #[test]
    fn paper_example_hollow_verb() {
        // قال → قول via Restore Original Form (paper §6.3, Fig 19)
        let r = stemmer().stem(&ArabicWord::encode("قال"));
        assert_eq!(root_str(&r), "قول");
        assert_eq!(r.kind, MatchKind::Restored);
    }

    #[test]
    fn paper_example_remove_infix() {
        // كاتب → كتب via Remove Infix (paper §6.3, Fig 18)
        let r = stemmer().stem(&ArabicWord::encode("كاتب"));
        assert_eq!(root_str(&r), "كتب");
        assert_eq!(r.kind, MatchKind::RmInfixTri);
    }

    #[test]
    fn remove_infix_bilateral() {
        // ماد → مد (tri stem with infix 2nd char → bilateral root)
        let r = stemmer().stem(&ArabicWord::encode("ماد"));
        assert_eq!(root_str(&r), "مد");
        assert_eq!(r.kind, MatchKind::RmInfixBi);
    }

    #[test]
    fn infix_disabled_returns_none() {
        let s = Stemmer::new(
            Arc::new(RootSet::builtin_mini()),
            StemmerConfig { infix_processing: false },
        );
        assert_eq!(s.stem(&ArabicWord::encode("قال")).kind, MatchKind::None);
        // ...but direct matches still work
        assert_eq!(s.stem(&ArabicWord::encode("يدرس")).kind, MatchKind::Tri);
    }

    #[test]
    fn unknown_word() {
        let r = stemmer().stem(&ArabicWord::encode("ظظظظظ"));
        assert_eq!(r, StemResult::NONE);
    }

    #[test]
    fn degenerate_inputs() {
        let s = stemmer();
        assert_eq!(s.stem(&ArabicWord::encode("")).kind, MatchKind::None);
        assert_eq!(s.stem(&ArabicWord::encode("ب")).kind, MatchKind::None);
        // bilateral roots are NOT directly matchable
        assert_eq!(s.stem(&ArabicWord::encode("مد")).kind, MatchKind::None);
    }

    #[test]
    fn suffix_length_cap() {
        // A valid root followed by 10 suffix letters exceeds MAX_SUFFIX=9:
        // درس + وووووووووو (10 waws)
        let w = ArabicWord::encode("درسوووووووووو");
        assert_eq!(w.len, 13);
        let r = stemmer().stem(&w);
        assert_eq!(r.kind, MatchKind::None);
        // 9 suffix letters is allowed
        let w9 = ArabicWord::encode("درسووووووووو");
        assert_eq!(stemmer().stem(&w9).kind, MatchKind::Tri);
    }

    #[test]
    fn tri_priority_over_quad() {
        // Both a tri and a quad interpretation may exist; tri wins (shared
        // contract). درسن: stem(0,3)=درس tri ✓ even though درسن(0,4) might
        // be a quad candidate.
        let r = stemmer().stem(&ArabicWord::encode("درسن"));
        assert_eq!(root_str(&r), "درس");
        assert_eq!(r.kind, MatchKind::Tri);
    }

    #[test]
    fn smaller_cut_wins() {
        // لعبت: p=0 gives لعب; even though p could be larger with other
        // letters, the smallest valid cut must win.
        let r = stemmer().stem(&ArabicWord::encode("لعبت"));
        assert_eq!(r.cut, 0);
        assert_eq!(root_str(&r), "لعب");
    }

    #[test]
    fn invalid_prefix_blocks_cut() {
        // خدرس: خ is not a prefix letter so p=1 is invalid → no match for درس.
        let r = stemmer().stem(&ArabicWord::encode("خدرس"));
        assert_eq!(r.kind, MatchKind::None);
    }

    /// The fused path and the reference path agree on the paper examples
    /// and on random letter soup, in both configs. (The heavyweight
    /// 10k-word inflected-corpus version lives in tests/proptests.rs.)
    #[test]
    fn fused_equals_reference() {
        let roots = Arc::new(RootSet::builtin_mini());
        let mut rng = SplitMix64::new(0xFA57);
        for infix in [true, false] {
            let s = Stemmer::new(roots.clone(), StemmerConfig { infix_processing: infix });
            for w in [
                "سيلعبون",
                "أفاستسقيناكموها",
                "فتزحزحت",
                "قال",
                "كاتب",
                "ماد",
                "درسوووووووووو",
                "خدرس",
                "",
                "hello",
            ] {
                let w = ArabicWord::encode(w);
                assert_eq!(s.stem(&w), s.stem_reference(&w), "word {w:?} infix={infix}");
            }
            for case in 0..2000 {
                let n = rng.index(MAX_WORD + 1);
                let codes: Vec<u16> =
                    (0..n).map(|_| chars::index_char(1 + rng.below(36) as u8)).collect();
                let w = ArabicWord::from_codes(&codes);
                assert_eq!(s.stem(&w), s.stem_reference(&w), "case {case} {w:?}");
            }
        }
    }

    /// The packed kernel is bit-identical to the array kernel — paper
    /// examples, random letter soup, and words with canonicalized
    /// non-Arabic characters, in both infix configs.
    #[test]
    fn packed_kernel_equals_fused() {
        let roots = Arc::new(RootSet::builtin_mini());
        let mut rng = SplitMix64::new(0x9AC7);
        for infix in [true, false] {
            let s = Stemmer::new(roots.clone(), StemmerConfig { infix_processing: infix });
            for w in [
                "سيلعبون",
                "أفاستسقيناكموها",
                "فتزحزحت",
                "قال",
                "كاتب",
                "ماد",
                "درسوووووووووو",
                "خدرس",
                "",
                "hello",
                "قاxل",
            ] {
                let w = ArabicWord::encode(w);
                assert_eq!(
                    s.stem_packed(PackedWord::pack(&w)),
                    s.stem(&w),
                    "word {w:?} infix={infix}"
                );
            }
            for case in 0..2000 {
                let n = rng.index(MAX_WORD + 1);
                let codes: Vec<u16> =
                    (0..n).map(|_| chars::index_char(1 + rng.below(36) as u8)).collect();
                let w = ArabicWord::from_codes(&codes);
                assert_eq!(
                    s.stem_packed(PackedWord::pack(&w)),
                    s.stem(&w),
                    "case {case} {w:?}"
                );
            }
        }
    }

    /// The packed batch kernel equals the scalar packed kernel and the
    /// array batch kernel word-for-word.
    #[test]
    fn packed_batch_matches_scalar_and_array() {
        let s = stemmer();
        let mut rng = SplitMix64::new(0x9ACB);
        let words: Vec<ArabicWord> = (0..3000)
            .map(|_| {
                let n = rng.index(MAX_WORD + 1);
                let codes: Vec<u16> =
                    (0..n).map(|_| chars::index_char(1 + rng.below(36) as u8)).collect();
                ArabicWord::from_codes(&codes)
            })
            .collect();
        let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();
        let batch = s.stem_batch_packed(&packed);
        let scalar: Vec<StemResult> = packed.iter().map(|&p| s.stem_packed(p)).collect();
        assert_eq!(batch, scalar);
        assert_eq!(batch, s.stem_batch(&words));
        assert!(s.stem_batch_packed(&[]).is_empty());
    }

    /// Batch kernels are per-word-equal to the scalar fused path.
    #[test]
    fn batch_kernels_match_scalar() {
        let s = stemmer();
        let mut rng = SplitMix64::new(0xBA7C);
        let words: Vec<ArabicWord> = (0..4000)
            .map(|_| {
                let n = rng.index(MAX_WORD + 1);
                let codes: Vec<u16> =
                    (0..n).map(|_| chars::index_char(1 + rng.below(36) as u8)).collect();
                ArabicWord::from_codes(&codes)
            })
            .collect();
        let scalar: Vec<StemResult> = words.iter().map(|w| s.stem(w)).collect();
        assert_eq!(s.stem_batch(&words), scalar);
        assert_eq!(s.stem_batch_parallel(&words, 4), scalar);
        // empty + tiny batches
        assert!(s.stem_batch(&[]).is_empty());
        assert!(s.stem_batch_parallel(&[], 4).is_empty());
        assert_eq!(s.stem_batch_parallel(&words[..3], 4), &scalar[..3]);
    }

    /// Parallel chunk widths land on SIMD lane multiples (satellite of
    /// PR 6) without dropping below the amortization floor.
    #[test]
    fn parallel_chunks_are_lane_multiples() {
        for len in [512usize, 1000, 4097, 10_000, 65_536, 1_000_001] {
            for workers in [2usize, 3, 4, 7, 8, 16] {
                let chunk = parallel_chunk_size(len, workers);
                assert_eq!(chunk % crate::simd::LANES, 0, "len {len} workers {workers}");
                assert!(chunk >= MIN_PARALLEL_CHUNK);
                // still wide enough to cover the batch with the claimed
                // number of chunks
                assert!(chunk * len.div_ceil(chunk) >= len);
            }
        }
        // the floor itself is already a lane multiple
        assert_eq!(MIN_PARALLEL_CHUNK % crate::simd::LANES, 0);
    }

    /// The dispatching packed batch, the explicit SIMD batch, and the
    /// pinned scalar batch agree word-for-word across the dispatch
    /// threshold in both infix configs.
    #[test]
    fn simd_dispatch_agrees_with_scalar_baseline() {
        let roots = Arc::new(RootSet::builtin_mini());
        let mut rng = SplitMix64::new(0x51D0);
        for infix in [true, false] {
            let s = Stemmer::new(roots.clone(), StemmerConfig { infix_processing: infix });
            for width in [0usize, 5, crate::simd::MIN_SIMD_BATCH - 1, 64, 333] {
                let words: Vec<ArabicWord> = (0..width)
                    .map(|_| {
                        let n = rng.index(MAX_WORD + 1);
                        let codes: Vec<u16> = (0..n)
                            .map(|_| chars::index_char(1 + rng.below(36) as u8))
                            .collect();
                        ArabicWord::from_codes(&codes)
                    })
                    .collect();
                let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();
                let baseline = s.stem_batch_packed_scalar(&packed);
                assert_eq!(s.stem_batch_packed(&packed), baseline, "width {width}");
                assert_eq!(s.stem_batch_simd(&packed), baseline, "width {width}");
                assert_eq!(s.stem_batch(&words), baseline, "width {width}");
            }
        }
    }

    #[test]
    fn soa_encoding_layout() {
        let words =
            [ArabicWord::encode("درس"), ArabicWord::encode(""), ArabicWord::encode("سيلعبون")];
        let b = SoaBatch::encode(&words);
        assert_eq!(b.indices.len(), 3 * MAX_WORD);
        assert_eq!(b.lens, vec![3, 0, 7]);
        assert_eq!(b.row(0)[..3], words[0].to_indices()[..3]);
        assert!(b.row(1).iter().all(|&i| i == 0));
        assert_eq!(b.profiles[2], AffixProfile::of(&words[2]));
    }
}
