//! The sequential software LB stemmer — the paper's Java baseline, ported.
//!
//! Semantics are the shared contract of DESIGN.md §6 and must agree
//! bit-for-bit with `python/compile/kernels/ref.py::ref_stem_word`, the JAX
//! model, and the HW simulator (cross-validation tests enforce this).

use crate::chars::{self, ArabicWord, MAX_SUFFIX};
use crate::roots::RootSet;
use std::sync::Arc;

/// How a root was found — mirrors `alphabet.py::KIND_*`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum MatchKind {
    /// No root extracted.
    None = 0,
    /// Direct trilateral dictionary match.
    Tri = 1,
    /// Direct quadrilateral dictionary match.
    Quad = 2,
    /// *Remove Infix* (Fig 18): quad stem, infix 2nd char dropped → trilateral.
    RmInfixTri = 3,
    /// *Remove Infix*: tri stem, infix 2nd char dropped → bilateral.
    RmInfixBi = 4,
    /// *Restore Original Form* (Fig 19): hollow verb, 2nd char ا→و → trilateral.
    Restored = 5,
}

impl MatchKind {
    pub fn from_u8(v: u8) -> MatchKind {
        match v {
            1 => MatchKind::Tri,
            2 => MatchKind::Quad,
            3 => MatchKind::RmInfixTri,
            4 => MatchKind::RmInfixBi,
            5 => MatchKind::Restored,
            _ => MatchKind::None,
        }
    }

    /// Did this extraction use one of the §6.3 infix algorithms?
    pub fn used_infix(self) -> bool {
        matches!(self, MatchKind::RmInfixTri | MatchKind::RmInfixBi | MatchKind::Restored)
    }
}

/// Result of root extraction for one word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StemResult {
    /// The extracted root, 0-padded to 4 characters.
    pub root: [u16; 4],
    pub kind: MatchKind,
    /// The winning prefix cut index `p` (0..=5).
    pub cut: u8,
}

impl StemResult {
    pub const NONE: StemResult = StemResult { root: [0; 4], kind: MatchKind::None, cut: 0 };

    pub fn root_len(&self) -> usize {
        self.root.iter().take_while(|&&c| c != 0).count()
    }

    pub fn root_word(&self) -> ArabicWord {
        ArabicWord::from_codes(&self.root[..self.root_len()])
    }
}

/// Configuration for the stemmer (Table 6 compares infix on/off).
#[derive(Clone, Copy, Debug)]
pub struct StemmerConfig {
    /// Enable the two §6.3 infix algorithms (Remove Infix, Restore Form).
    pub infix_processing: bool,
}

impl Default for StemmerConfig {
    fn default() -> Self {
        StemmerConfig { infix_processing: true }
    }
}

/// The sequential linguistic-based stemmer.
pub struct Stemmer {
    roots: Arc<RootSet>,
    config: StemmerConfig,
}

impl Stemmer {
    pub fn new(roots: Arc<RootSet>, config: StemmerConfig) -> Self {
        Stemmer { roots, config }
    }

    pub fn with_defaults(roots: Arc<RootSet>) -> Self {
        Self::new(roots, StemmerConfig::default())
    }

    pub fn roots(&self) -> &RootSet {
        &self.roots
    }

    pub fn config(&self) -> StemmerConfig {
        self.config
    }

    /// Is the window `word[p..p+size]` a valid stem candidate?
    /// (DESIGN.md §6 shared contract — `ref.candidate_valid`.)
    fn candidate_valid(w: &ArabicWord, p: usize, size: usize) -> bool {
        let n = w.len;
        if p + size > n || n - (p + size) > MAX_SUFFIX {
            return false;
        }
        if !w.chars[..p].iter().all(|&c| chars::is_prefix_letter(c)) {
            return false;
        }
        w.chars[p + size..n].iter().all(|&c| chars::is_suffix_letter(c))
    }

    /// Extract the verb root of `w`. Priority: direct tri, direct quad,
    /// remove-infix tri, remove-infix bi, restored form; smaller cut first.
    pub fn stem(&self, w: &ArabicWord) -> StemResult {
        // Passes 1–2: direct trilateral then quadrilateral.
        for p in 0..chars::MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 3) {
                let stem = [w.chars[p], w.chars[p + 1], w.chars[p + 2]];
                if self.roots.tri.contains(&stem) {
                    return StemResult {
                        root: [stem[0], stem[1], stem[2], 0],
                        kind: MatchKind::Tri,
                        cut: p as u8,
                    };
                }
            }
        }
        for p in 0..chars::MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 4) {
                let stem = [w.chars[p], w.chars[p + 1], w.chars[p + 2], w.chars[p + 3]];
                if self.roots.quad.contains(&stem) {
                    return StemResult { root: stem, kind: MatchKind::Quad, cut: p as u8 };
                }
            }
        }
        if !self.config.infix_processing {
            return StemResult::NONE;
        }
        // Pass 3: Remove Infix on quadrilateral stems → trilateral roots.
        for p in 0..chars::MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 4) && chars::is_infix_letter(w.chars[p + 1]) {
                let red = [w.chars[p], w.chars[p + 2], w.chars[p + 3]];
                if self.roots.tri.contains(&red) {
                    return StemResult {
                        root: [red[0], red[1], red[2], 0],
                        kind: MatchKind::RmInfixTri,
                        cut: p as u8,
                    };
                }
            }
        }
        // Pass 4: Remove Infix on trilateral stems → bilateral roots.
        for p in 0..chars::MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 3) && chars::is_infix_letter(w.chars[p + 1]) {
                let red = [w.chars[p], w.chars[p + 2]];
                if self.roots.bi.contains(&red) {
                    return StemResult {
                        root: [red[0], red[1], 0, 0],
                        kind: MatchKind::RmInfixBi,
                        cut: p as u8,
                    };
                }
            }
        }
        // Pass 5: Restore Original Form (hollow verbs): 2nd char ا → و.
        for p in 0..chars::MAX_PREFIX + 1 {
            if Self::candidate_valid(w, p, 3) && w.chars[p + 1] == chars::ALEF {
                let res = [w.chars[p], chars::WAW, w.chars[p + 2]];
                if self.roots.tri.contains(&res) {
                    return StemResult {
                        root: [res[0], res[1], res[2], 0],
                        kind: MatchKind::Restored,
                        cut: p as u8,
                    };
                }
            }
        }
        StemResult::NONE
    }

    /// Convenience: stem a batch sequentially (the paper's software loop).
    pub fn stem_batch(&self, words: &[ArabicWord]) -> Vec<StemResult> {
        words.iter().map(|w| self.stem(w)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn stemmer() -> Stemmer {
        Stemmer::with_defaults(Arc::new(RootSet::builtin_mini()))
    }

    fn root_str(r: &StemResult) -> String {
        r.root_word().to_string_ar()
    }

    #[test]
    fn paper_example_silabun() {
        // سيلعبون → لعب (paper §3.1, Table 3)
        let r = stemmer().stem(&ArabicWord::encode("سيلعبون"));
        assert_eq!(root_str(&r), "لعب");
        assert_eq!(r.kind, MatchKind::Tri);
        assert_eq!(r.cut, 2);
    }

    #[test]
    fn paper_example_longest_word() {
        // أفاستسقيناكموها → سقي (paper §3.1, Fig 13)
        let r = stemmer().stem(&ArabicWord::encode("أفاستسقيناكموها"));
        assert_eq!(root_str(&r), "سقي");
        assert_eq!(r.kind, MatchKind::Tri);
    }

    #[test]
    fn paper_example_quadrilateral() {
        // فتزحزحت → زحزح (paper Fig 14)
        let r = stemmer().stem(&ArabicWord::encode("فتزحزحت"));
        assert_eq!(root_str(&r), "زحزح");
        assert_eq!(r.kind, MatchKind::Quad);
    }

    #[test]
    fn paper_example_hollow_verb() {
        // قال → قول via Restore Original Form (paper §6.3, Fig 19)
        let r = stemmer().stem(&ArabicWord::encode("قال"));
        assert_eq!(root_str(&r), "قول");
        assert_eq!(r.kind, MatchKind::Restored);
    }

    #[test]
    fn paper_example_remove_infix() {
        // كاتب → كتب via Remove Infix (paper §6.3, Fig 18)
        let r = stemmer().stem(&ArabicWord::encode("كاتب"));
        assert_eq!(root_str(&r), "كتب");
        assert_eq!(r.kind, MatchKind::RmInfixTri);
    }

    #[test]
    fn remove_infix_bilateral() {
        // ماد → مد (tri stem with infix 2nd char → bilateral root)
        let r = stemmer().stem(&ArabicWord::encode("ماد"));
        assert_eq!(root_str(&r), "مد");
        assert_eq!(r.kind, MatchKind::RmInfixBi);
    }

    #[test]
    fn infix_disabled_returns_none() {
        let s = Stemmer::new(
            Arc::new(RootSet::builtin_mini()),
            StemmerConfig { infix_processing: false },
        );
        assert_eq!(s.stem(&ArabicWord::encode("قال")).kind, MatchKind::None);
        // ...but direct matches still work
        assert_eq!(s.stem(&ArabicWord::encode("يدرس")).kind, MatchKind::Tri);
    }

    #[test]
    fn unknown_word() {
        let r = stemmer().stem(&ArabicWord::encode("ظظظظظ"));
        assert_eq!(r, StemResult::NONE);
    }

    #[test]
    fn degenerate_inputs() {
        let s = stemmer();
        assert_eq!(s.stem(&ArabicWord::encode("")).kind, MatchKind::None);
        assert_eq!(s.stem(&ArabicWord::encode("ب")).kind, MatchKind::None);
        // bilateral roots are NOT directly matchable
        assert_eq!(s.stem(&ArabicWord::encode("مد")).kind, MatchKind::None);
    }

    #[test]
    fn suffix_length_cap() {
        // A valid root followed by 10 suffix letters exceeds MAX_SUFFIX=9:
        // درس + وووووووووو (10 waws)
        let w = ArabicWord::encode("درسوووووووووو");
        assert_eq!(w.len, 13);
        let r = stemmer().stem(&w);
        assert_eq!(r.kind, MatchKind::None);
        // 9 suffix letters is allowed
        let w9 = ArabicWord::encode("درسووووووووو");
        assert_eq!(stemmer().stem(&w9).kind, MatchKind::Tri);
    }

    #[test]
    fn tri_priority_over_quad() {
        // Both a tri and a quad interpretation may exist; tri wins (shared
        // contract). درسن: stem(0,3)=درس tri ✓ even though درسن(0,4) might
        // be a quad candidate.
        let r = stemmer().stem(&ArabicWord::encode("درسن"));
        assert_eq!(root_str(&r), "درس");
        assert_eq!(r.kind, MatchKind::Tri);
    }

    #[test]
    fn smaller_cut_wins() {
        // لعبت: p=0 gives لعب; even though p could be larger with other
        // letters, the smallest valid cut must win.
        let r = stemmer().stem(&ArabicWord::encode("لعبت"));
        assert_eq!(r.cut, 0);
        assert_eq!(root_str(&r), "لعب");
    }

    #[test]
    fn invalid_prefix_blocks_cut() {
        // خدرس: خ is not a prefix letter so p=1 is invalid → no match for درس.
        let r = stemmer().stem(&ArabicWord::encode("خدرس"));
        assert_eq!(r.kind, MatchKind::None);
    }
}
