//! Performance metric substrate — the paper's §6.2 metric set (ET, TH) for
//! software plus latency histograms for the serving path. The hardware-only
//! metrics (PD, LUT, LR, PC) live in [`crate::hw::area`].
//!
//! The serving-path metrics are built on [`LatencyHistogram`], a lock-free
//! log₂-bucketed microsecond histogram: one atomic increment per sample,
//! percentiles read from bucket upper bounds. [`ServiceMetrics`] bundles it
//! with request/batch/saturation counters; the same histogram type is
//! reused standalone by the `ama loadtest` client fleet for client-side
//! round-trip latency.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
// Concurrency facade (PR 10): std re-exports in normal builds, the chk
// model-checker instrumentation under `--features chk`.
use crate::chk::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::chk::sync::{Arc, Mutex};
use crate::chk::thread::JoinHandle;
use crate::chk::time::Instant;
use std::time::Duration;

/// Execution-time / throughput measurement of a finished run.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub words: u64,
    pub elapsed: Duration,
}

impl Measurement {
    /// TH, in words per second (the paper's Wps).
    pub fn wps(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.words as f64 / self.elapsed.as_secs_f64()
    }
}

/// Time a closure over a word count.
pub fn measure<F: FnOnce()>(words: u64, f: F) -> Measurement {
    let start = Instant::now();
    f();
    Measurement { words, elapsed: start.elapsed() }
}

/// Number of log₂ microsecond buckets: bucket `i` counts samples in
/// `[2^i, 2^(i+1))` µs, with the last bucket absorbing everything larger
/// (≈ 2 s and up).
pub const LATENCY_BUCKETS: usize = 21;

/// Lock-free log₂-bucketed latency histogram (microsecond resolution).
///
/// Recording is one relaxed atomic increment; percentile reads return the
/// upper bound of the bucket containing the requested quantile (i.e. a
/// ≤2× overestimate, which is the right bias for tail-latency reporting).
#[derive(Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().max(1) as u64);
    }

    pub fn record_us(&self, us: u64) {
        let us = us.max(1);
        let bucket = (63 - us.leading_zeros() as usize).min(LATENCY_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
    }

    /// Per-bucket counts (index `i` covers `[2^i, 2^(i+1))` µs).
    pub fn counts(&self) -> [u64; LATENCY_BUCKETS] {
        let mut out = [0u64; LATENCY_BUCKETS];
        for (o, b) in out.iter_mut().zip(&self.buckets) {
            *o = b.load(Ordering::Relaxed); // ord: Relaxed — stats
        }
        out
    }

    pub fn total(&self) -> u64 {
        self.counts().iter().sum()
    }

    /// Approximate latency percentile, in µs (upper bucket bound);
    /// 0 when the histogram is empty.
    pub fn percentile_us(&self, q: f64) -> u64 {
        let counts = self.counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << LATENCY_BUCKETS
    }
}

/// Lock-free service counters shared across coordinator threads.
#[derive(Default)]
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub words: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Total words across batches, for mean batch-size accounting.
    pub batched_words: AtomicU64,
    /// Saturation counter: submissions that found the request queue full
    /// and had to block (backpressure engaged).
    pub queue_full_events: AtomicU64,
    /// Saturation counter: submissions that found the reply slab exhausted
    /// (every reply slot in flight) and had to wait for capacity.
    pub slab_waits: AtomicU64,
    /// Typed rejections (PR 3): requests answered with the protocol error
    /// `QUEUE_FULL` (request queue stayed full past the submit deadline).
    pub rejected_queue_full: AtomicU64,
    /// Typed rejections: requests answered with `SHUTDOWN` (coordinator
    /// closed before or during submission).
    pub rejected_shutdown: AtomicU64,
    /// Typed rejections: envelopes answered with `BAD_WORD` (empty or
    /// non-Arabic word in an AMA/1 batch).
    pub rejected_bad_word: AtomicU64,
    /// Stem-cache probes answered from the cache (PR 4): requests that
    /// never reached a kernel. Counted by the cache-fronted
    /// `RegistryBackend`; zero when serving without a cache.
    pub cache_hits: AtomicU64,
    /// Stem-cache probes that fell through to kernel dispatch (and then
    /// seeded the cache).
    pub cache_misses: AtomicU64,
    /// Words analyzed per algorithm (PR 9), indexed by
    /// [`crate::analysis::Algorithm`] discriminant. Exported as the
    /// `ama_algorithm_words_total{algorithm=…}` Prometheus series.
    pub algo_words: [AtomicU64; crate::analysis::Algorithm::ALL.len()],
    /// Histogram of request latency (submit → reply fill).
    latency: LatencyHistogram,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, words: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        self.batched_words.fetch_add(words, Ordering::Relaxed); // ord: Relaxed — stats
        self.words.fetch_add(words, Ordering::Relaxed); // ord: Relaxed — stats
    }

    pub fn record_latency(&self, d: Duration) {
        self.latency.record(d);
        self.requests.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
    }

    /// Attribute `words` to the algorithm that analyzed them (per-batch,
    /// from the coordinator's per-`EngineOpts` dispatch groups).
    pub fn record_algorithm_words(&self, algo: crate::analysis::Algorithm, words: u64) {
        self.algo_words[algo as usize].fetch_add(words, Ordering::Relaxed); // ord: Relaxed — stats
    }

    /// The request-latency histogram (shared shape with client-side
    /// histograms in the load harness).
    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed); // ord: Relaxed — stats
        if b == 0 {
            return 0.0;
        }
        // ord: Relaxed — statistics counter; no ordering required.
        self.batched_words.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency percentile from the log2 histogram, in µs
    /// (upper bucket bound).
    pub fn latency_percentile_us(&self, q: f64) -> u64 {
        self.latency.percentile_us(q)
    }

    /// Count a typed protocol rejection (`None` for codes without a
    /// dedicated counter — they still show up in `errors` where counted
    /// by the caller).
    pub fn record_rejection(&self, code: crate::analysis::ErrorCode) {
        use crate::analysis::ErrorCode;
        match code {
            ErrorCode::QueueFull => &self.rejected_queue_full,
            ErrorCode::Shutdown => &self.rejected_shutdown,
            ErrorCode::BadWord => &self.rejected_bad_word,
            _ => return,
        }
        .fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed), // ord: Relaxed — stats
            words: self.words.load(Ordering::Relaxed), // ord: Relaxed — stats
            batches: self.batches.load(Ordering::Relaxed), // ord: Relaxed — stats
            errors: self.errors.load(Ordering::Relaxed), // ord: Relaxed — stats
            queue_full_events: self.queue_full_events.load(Ordering::Relaxed), // ord: Relaxed — stats
            slab_waits: self.slab_waits.load(Ordering::Relaxed), // ord: Relaxed — stats
            // ord: Relaxed — statistics counter; no ordering required.
            rejected_queue_full: self.rejected_queue_full.load(Ordering::Relaxed),
            rejected_shutdown: self.rejected_shutdown.load(Ordering::Relaxed), // ord: Relaxed — stats
            rejected_bad_word: self.rejected_bad_word.load(Ordering::Relaxed), // ord: Relaxed — stats
            cache_hits: self.cache_hits.load(Ordering::Relaxed), // ord: Relaxed — stats
            cache_misses: self.cache_misses.load(Ordering::Relaxed), // ord: Relaxed — stats
            algo_words: {
                let mut a = [0u64; crate::analysis::Algorithm::ALL.len()];
                for (o, c) in a.iter_mut().zip(&self.algo_words) {
                    *o = c.load(Ordering::Relaxed); // ord: Relaxed — stats
                }
                a
            },
            mean_batch_size: self.mean_batch_size(),
            p50_us: self.latency.percentile_us(0.50),
            p90_us: self.latency.percentile_us(0.90),
            p99_us: self.latency.percentile_us(0.99),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub words: u64,
    pub batches: u64,
    pub errors: u64,
    pub queue_full_events: u64,
    pub slab_waits: u64,
    pub rejected_queue_full: u64,
    pub rejected_shutdown: u64,
    pub rejected_bad_word: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub algo_words: [u64; crate::analysis::Algorithm::ALL.len()],
    pub mean_batch_size: f64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
}

impl MetricsSnapshot {
    /// Fraction of cache probes that hit (0.0 with no probes — i.e. no
    /// cache configured or nothing served yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let probes = self.cache_hits + self.cache_misses;
        if probes == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / probes as f64
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} words={} batches={} mean_batch={:.1} p50={}us p90={}us p99={}us \
             queue_full={} slab_waits={} errors={} \
             rejected[queue_full={} shutdown={} bad_word={}] \
             cache[hits={} misses={} rate={:.3}]",
            self.requests,
            self.words,
            self.batches,
            self.mean_batch_size,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.queue_full_events,
            self.slab_waits,
            self.errors,
            self.rejected_queue_full,
            self.rejected_shutdown,
            self.rejected_bad_word,
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate()
        )
    }
}

/// Lock-free gateway-tier counters (PR 7): one instance per running
/// gateway, shared across its connection handlers and the health prober.
/// Everything is a relaxed atomic increment on the hot path; the chaos
/// harness reads a [`GatewaySnapshot`] to assert breaker transitions and
/// loss-free failover.
#[derive(Default)]
pub struct GatewayMetrics {
    /// AMA/1 envelopes accepted on the front side.
    pub envelopes: AtomicU64,
    /// Words carried by those envelopes.
    pub words: AtomicU64,
    /// Backend dispatch groups actually sent to replicas (after sharding
    /// and coalescing collapse).
    pub backend_dispatches: AtomicU64,
    /// Words sent to replicas. `words - backend_words` is the coalescing
    /// + dedup savings.
    pub backend_words: AtomicU64,
    /// Words answered by piggybacking on an identical in-flight dispatch
    /// (never reached a replica).
    pub coalesced_words: AtomicU64,
    /// Backend attempts beyond the first for a dispatch group (backoff
    /// retries on the same endpoint).
    pub retries: AtomicU64,
    /// Dispatch groups rerouted to a different replica after their shard
    /// owner failed.
    pub failovers: AtomicU64,
    /// Breaker transitions closed→open (trip).
    pub breaker_opened: AtomicU64,
    /// Breaker transitions open→half-open (cooldown expired, trial
    /// request admitted).
    pub breaker_half_opened: AtomicU64,
    /// Breaker transitions half-open→closed (trial succeeded; replica
    /// recovered).
    pub breaker_closed: AtomicU64,
    /// Front-side requests shed by the per-client token bucket
    /// (`RATE_LIMITED`).
    pub shed_rate_limited: AtomicU64,
    /// Front-side requests shed by the per-client in-flight cap
    /// (`RATE_LIMITED` with retry-after, no token consumed).
    pub shed_overloaded: AtomicU64,
    /// Requests answered `UNAVAILABLE` (no healthy replica within the
    /// retry/deadline budget).
    pub unavailable: AtomicU64,
    /// Background health-probe failures (prober-side view of outages).
    pub probe_failures: AtomicU64,
    /// Front-side request latency (envelope read → reply written).
    latency: LatencyHistogram,
}

impl GatewayMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_envelope(&self, words: u64) {
        self.envelopes.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        self.words.fetch_add(words, Ordering::Relaxed); // ord: Relaxed — stats
    }

    pub fn record_dispatch(&self, words: u64) {
        self.backend_dispatches.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        self.backend_words.fetch_add(words, Ordering::Relaxed); // ord: Relaxed — stats
    }

    pub fn record_latency(&self, d: Duration) {
        self.latency.record(d);
    }

    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    pub fn snapshot(&self) -> GatewaySnapshot {
        GatewaySnapshot {
            envelopes: self.envelopes.load(Ordering::Relaxed), // ord: Relaxed — stats
            words: self.words.load(Ordering::Relaxed), // ord: Relaxed — stats
            // ord: Relaxed — statistics counter; no ordering required.
            backend_dispatches: self.backend_dispatches.load(Ordering::Relaxed),
            backend_words: self.backend_words.load(Ordering::Relaxed), // ord: Relaxed — stats
            coalesced_words: self.coalesced_words.load(Ordering::Relaxed), // ord: Relaxed — stats
            retries: self.retries.load(Ordering::Relaxed), // ord: Relaxed — stats
            failovers: self.failovers.load(Ordering::Relaxed), // ord: Relaxed — stats
            breaker_opened: self.breaker_opened.load(Ordering::Relaxed), // ord: Relaxed — stats
            // ord: Relaxed — statistics counter; no ordering required.
            breaker_half_opened: self.breaker_half_opened.load(Ordering::Relaxed),
            breaker_closed: self.breaker_closed.load(Ordering::Relaxed), // ord: Relaxed — stats
            shed_rate_limited: self.shed_rate_limited.load(Ordering::Relaxed), // ord: Relaxed — stats
            shed_overloaded: self.shed_overloaded.load(Ordering::Relaxed), // ord: Relaxed — stats
            unavailable: self.unavailable.load(Ordering::Relaxed), // ord: Relaxed — stats
            probe_failures: self.probe_failures.load(Ordering::Relaxed), // ord: Relaxed — stats
            p50_us: self.latency.percentile_us(0.50),
            p90_us: self.latency.percentile_us(0.90),
            p99_us: self.latency.percentile_us(0.99),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct GatewaySnapshot {
    pub envelopes: u64,
    pub words: u64,
    pub backend_dispatches: u64,
    pub backend_words: u64,
    pub coalesced_words: u64,
    pub retries: u64,
    pub failovers: u64,
    pub breaker_opened: u64,
    pub breaker_half_opened: u64,
    pub breaker_closed: u64,
    pub shed_rate_limited: u64,
    pub shed_overloaded: u64,
    pub unavailable: u64,
    pub probe_failures: u64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
}

impl GatewaySnapshot {
    /// Fraction of front-side words that never cost a backend dispatch
    /// (coalesced onto an identical in-flight request).
    pub fn coalesce_rate(&self) -> f64 {
        if self.words == 0 {
            return 0.0;
        }
        self.coalesced_words as f64 / self.words as f64
    }
}

impl std::fmt::Display for GatewaySnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "envelopes={} words={} p50={}us p90={}us p99={}us \
             backend[dispatches={} words={}] coalesced={} ({:.3}) \
             retries={} failovers={} \
             breaker[opened={} half_opened={} closed={}] \
             shed[rate_limited={} overloaded={}] unavailable={} probe_failures={}",
            self.envelopes,
            self.words,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.backend_dispatches,
            self.backend_words,
            self.coalesced_words,
            self.coalesce_rate(),
            self.retries,
            self.failovers,
            self.breaker_opened,
            self.breaker_half_opened,
            self.breaker_closed,
            self.shed_rate_limited,
            self.shed_overloaded,
            self.unavailable,
            self.probe_failures
        )
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition (PR 9)
// ---------------------------------------------------------------------------

/// Builder for the Prometheus text exposition format
/// (`text/plain; version=0.0.4`): `# HELP` / `# TYPE` headers plus one
/// sample line per series. Hand-rolled like the JSON shim — the format
/// is line-oriented and needs no escaping for our names/labels.
#[derive(Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        use std::fmt::Write as _;
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    pub fn counter(&mut self, name: &str, help: &str, value: u64) {
        use std::fmt::Write as _;
        self.header(name, help, "counter");
        let _ = writeln!(self.out, "{name} {value}");
    }

    pub fn gauge(&mut self, name: &str, help: &str, value: u64) {
        use std::fmt::Write as _;
        self.header(name, help, "gauge");
        let _ = writeln!(self.out, "{name} {value}");
    }

    pub fn gauge_f64(&mut self, name: &str, help: &str, value: f64) {
        use std::fmt::Write as _;
        self.header(name, help, "gauge");
        let _ = writeln!(self.out, "{name} {value:.6}");
    }

    /// One metric with several label sets; each row is
    /// (`key="value"` label body, sample value).
    pub fn labeled_counter(&mut self, name: &str, help: &str, rows: &[(String, u64)]) {
        use std::fmt::Write as _;
        self.header(name, help, "counter");
        for (labels, value) in rows {
            let _ = writeln!(self.out, "{name}{{{labels}}} {value}");
        }
    }

    /// Same, for gauges (e.g. per-loop open-connection counts).
    pub fn labeled_gauge(&mut self, name: &str, help: &str, rows: &[(String, u64)]) {
        use std::fmt::Write as _;
        self.header(name, help, "gauge");
        for (labels, value) in rows {
            let _ = writeln!(self.out, "{name}{{{labels}}} {value}");
        }
    }

    /// Cumulative histogram from a [`LatencyHistogram`], converted to
    /// seconds. `_sum` is approximated from bucket upper bounds (the
    /// log₂ histogram stores no exact sum) — a ≤2× overestimate,
    /// consistent with the percentile bias.
    pub fn histogram_seconds(&mut self, name: &str, help: &str, h: &LatencyHistogram) {
        use std::fmt::Write as _;
        self.header(name, help, "histogram");
        let counts = h.counts();
        let total: u64 = counts.iter().sum();
        let mut cum = 0u64;
        let mut sum_us = 0.0f64;
        for (i, c) in counts.iter().enumerate() {
            cum += c;
            let le_us = 1u64 << (i + 1);
            let _ = writeln!(self.out, "{name}_bucket{{le=\"{}\"}} {cum}", le_us as f64 / 1e6);
            sum_us += *c as f64 * le_us as f64;
        }
        let _ = writeln!(self.out, "{name}_bucket{{le=\"+Inf\"}} {total}");
        let _ = writeln!(self.out, "{name}_sum {:.6}", sum_us / 1e6);
        let _ = writeln!(self.out, "{name}_count {total}");
    }

    pub fn finish(self) -> String {
        self.out
    }
}

impl ServiceMetrics {
    /// Render the full `ama_*` serving-tier series set into `out`.
    pub fn render_prometheus(&self, out: &mut PromText) {
        let snap = self.snapshot();
        out.counter("ama_requests_total", "Requests answered by the coordinator", snap.requests);
        out.counter("ama_words_total", "Words analyzed", snap.words);
        out.counter("ama_batches_total", "Kernel dispatch batches", snap.batches);
        out.counter("ama_errors_total", "Batches failed inside a kernel", snap.errors);
        out.gauge_f64("ama_mean_batch_size", "Mean words per dispatch batch", snap.mean_batch_size);
        out.counter(
            "ama_queue_full_events_total",
            "Submissions that found the request queue full (saturation)",
            snap.queue_full_events,
        );
        out.counter(
            "ama_slab_waits_total",
            "Submissions that waited on an exhausted reply slab (saturation)",
            snap.slab_waits,
        );
        out.labeled_counter(
            "ama_rejected_total",
            "Typed protocol rejections by reason",
            &[
                ("reason=\"queue_full\"".to_string(), snap.rejected_queue_full),
                ("reason=\"shutdown\"".to_string(), snap.rejected_shutdown),
                ("reason=\"bad_word\"".to_string(), snap.rejected_bad_word),
            ],
        );
        out.counter("ama_cache_hits_total", "Stem-cache probes answered from cache", snap.cache_hits);
        out.counter(
            "ama_cache_misses_total",
            "Stem-cache probes that reached a kernel",
            snap.cache_misses,
        );
        out.gauge_f64(
            "ama_cache_hit_rate",
            "Fraction of cache probes that hit (0 with no cache)",
            snap.cache_hit_rate(),
        );
        let algo_rows: Vec<(String, u64)> = crate::analysis::Algorithm::ALL
            .iter()
            .map(|a| (format!("algorithm=\"{}\"", a.as_str()), snap.algo_words[*a as usize]))
            .collect();
        out.labeled_counter(
            "ama_algorithm_words_total",
            "Words analyzed per stemming algorithm",
            &algo_rows,
        );
        out.histogram_seconds(
            "ama_request_latency_seconds",
            "Request latency, submit to reply fill (log2 buckets)",
            self.latency(),
        );
    }
}

impl GatewayMetrics {
    /// Render the full `ama_gateway_*` series set into `out`.
    pub fn render_prometheus(&self, out: &mut PromText) {
        let snap = self.snapshot();
        out.counter("ama_gateway_envelopes_total", "AMA/1 envelopes accepted", snap.envelopes);
        out.counter("ama_gateway_words_total", "Words carried by accepted envelopes", snap.words);
        out.counter(
            "ama_gateway_backend_dispatches_total",
            "Dispatch groups sent to replicas",
            snap.backend_dispatches,
        );
        out.counter("ama_gateway_backend_words_total", "Words sent to replicas", snap.backend_words);
        out.counter(
            "ama_gateway_coalesced_words_total",
            "Words answered by piggybacking on in-flight dispatches",
            snap.coalesced_words,
        );
        out.counter("ama_gateway_retries_total", "Backend attempts beyond the first", snap.retries);
        out.counter("ama_gateway_failovers_total", "Dispatch groups rerouted after shard-owner failure", snap.failovers);
        out.labeled_counter(
            "ama_gateway_breaker_transitions_total",
            "Circuit-breaker transitions by kind",
            &[
                ("transition=\"opened\"".to_string(), snap.breaker_opened),
                ("transition=\"half_opened\"".to_string(), snap.breaker_half_opened),
                ("transition=\"closed\"".to_string(), snap.breaker_closed),
            ],
        );
        out.labeled_counter(
            "ama_gateway_shed_total",
            "Front-side requests shed by reason",
            &[
                ("reason=\"rate_limited\"".to_string(), snap.shed_rate_limited),
                ("reason=\"overloaded\"".to_string(), snap.shed_overloaded),
            ],
        );
        out.counter(
            "ama_gateway_unavailable_total",
            "Requests answered UNAVAILABLE (no healthy replica)",
            snap.unavailable,
        );
        out.counter(
            "ama_gateway_probe_failures_total",
            "Background health-probe failures",
            snap.probe_failures,
        );
        out.histogram_seconds(
            "ama_gateway_request_latency_seconds",
            "Front-side request latency, read to reply (log2 buckets)",
            self.latency(),
        );
    }
}

/// Minimal blocking HTTP endpoint serving `GET /metrics` in Prometheus
/// text format on a side port (PR 9). One short-lived connection per
/// scrape — scrape cadence is seconds, so a single blocking thread is
/// the right amount of machinery; the C10K event loop stays dedicated
/// to protocol traffic.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Mutex<Option<JoinHandle<()>>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 picks a free port) and serve `render()` as
    /// the `/metrics` body until [`MetricsServer::stop`].
    pub fn start(
        addr: &str,
        render: Arc<dyn Fn() -> String + Send + Sync>,
    ) -> std::io::Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let join = crate::chk::thread::Builder::new().name("metrics-http".into()).spawn(move || {
            for stream in listener.incoming() {
                // ord: Acquire — stop-flag poll; pairs with the Release
                // store in stop().
                if stop_t.load(Ordering::Acquire) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                serve_scrape(stream, render.as_ref());
            }
        })?;
        Ok(MetricsServer { addr: local, stop, join: Mutex::new(Some(join)) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the endpoint: flag + self-poke + join.
    pub fn stop(&self) {
        // ord: Release — stop-flag publication; the accept loop polls
        // with Acquire. Was SeqCst; nothing cross-variable here.
        self.stop.store(true, Ordering::Release);
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.join.lock().unwrap().take() {
            let _ = j.join();
        }
    }
}

/// Answer one scrape connection: parse the request line, serve
/// `/metrics` or 404, close.
fn serve_scrape(mut stream: TcpStream, render: &dyn Fn() -> String) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    let mut req = Vec::new();
    let mut buf = [0u8; 1024];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                req.extend_from_slice(&buf[..n]);
                if req.windows(4).any(|w| w == b"\r\n\r\n") || req.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let line = req.split(|&b| b == b'\n').next().unwrap_or(&[]);
    let line = String::from_utf8_lossy(line);
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    let (status, body) = if method == "GET" && (path == "/metrics" || path.starts_with("/metrics?"))
    {
        ("200 OK", render())
    } else {
        ("404 Not Found", "only /metrics lives here\n".to_string())
    };
    let resp = format!(
        "HTTP/1.0 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(resp.as_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wps_computation() {
        let m = Measurement { words: 1000, elapsed: Duration::from_millis(500) };
        assert!((m.wps() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn zero_elapsed_guard() {
        let m = Measurement { words: 10, elapsed: Duration::ZERO };
        assert_eq!(m.wps(), 0.0);
    }

    #[test]
    fn latency_histogram_percentiles() {
        let s = ServiceMetrics::new();
        for _ in 0..99 {
            s.record_latency(Duration::from_micros(100)); // bucket ~6
        }
        s.record_latency(Duration::from_millis(10)); // bucket ~13
        let p50 = s.latency_percentile_us(0.5);
        let p99 = s.latency_percentile_us(0.99);
        assert!(p50 <= 256, "p50 {p50}");
        assert!(p99 <= 256, "p99 {p99}"); // 99th of 100 is still the fast bucket
        let p100 = s.latency_percentile_us(1.0);
        assert!(p100 >= 8192, "p100 {p100}");
    }

    #[test]
    fn standalone_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.5), 0); // empty
        h.record_us(3);
        h.record_us(3_000_000); // past the last bucket bound (2^21 µs)
        assert_eq!(h.total(), 2);
        assert!(h.percentile_us(0.5) <= 4);
        assert!(h.percentile_us(1.0) >= 1 << 20);
        // the last bucket absorbs out-of-range samples
        h.record_us(u64::MAX);
        assert_eq!(h.counts()[LATENCY_BUCKETS - 1], 2);
    }

    #[test]
    fn batch_accounting() {
        let s = ServiceMetrics::new();
        s.record_batch(10);
        s.record_batch(30);
        assert_eq!(s.mean_batch_size(), 20.0);
        assert_eq!(s.snapshot().words, 40);
    }

    #[test]
    fn rejection_counters_roundtrip() {
        use crate::analysis::ErrorCode;
        let s = ServiceMetrics::new();
        s.record_rejection(ErrorCode::QueueFull);
        s.record_rejection(ErrorCode::Shutdown);
        s.record_rejection(ErrorCode::Shutdown);
        s.record_rejection(ErrorCode::BadWord);
        s.record_rejection(ErrorCode::Timeout); // no dedicated counter
        let snap = s.snapshot();
        assert_eq!(snap.rejected_queue_full, 1);
        assert_eq!(snap.rejected_shutdown, 2);
        assert_eq!(snap.rejected_bad_word, 1);
        let line = format!("{snap}");
        assert!(line.contains("rejected[queue_full=1 shutdown=2 bad_word=1]"), "{line}");
    }

    #[test]
    fn cache_counters_and_hit_rate() {
        let s = ServiceMetrics::new();
        assert_eq!(s.snapshot().cache_hit_rate(), 0.0, "no probes → 0.0");
        s.cache_hits.fetch_add(3, Ordering::Relaxed); // ord: Relaxed — stats
        s.cache_misses.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        let snap = s.snapshot();
        assert_eq!(snap.cache_hits, 3);
        assert_eq!(snap.cache_misses, 1);
        assert!((snap.cache_hit_rate() - 0.75).abs() < 1e-9);
        let line = format!("{snap}");
        assert!(line.contains("cache[hits=3 misses=1 rate=0.750]"), "{line}");
    }

    #[test]
    fn gateway_counters_and_display() {
        let g = GatewayMetrics::new();
        g.record_envelope(8);
        g.record_envelope(4);
        g.record_dispatch(9);
        g.coalesced_words.fetch_add(3, Ordering::Relaxed); // ord: Relaxed — stats
        g.breaker_opened.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        g.breaker_half_opened.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        g.breaker_closed.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        g.shed_rate_limited.fetch_add(2, Ordering::Relaxed); // ord: Relaxed — stats
        g.unavailable.fetch_add(5, Ordering::Relaxed); // ord: Relaxed — stats
        g.record_latency(Duration::from_micros(100));
        let snap = g.snapshot();
        assert_eq!(snap.envelopes, 2);
        assert_eq!(snap.words, 12);
        assert_eq!(snap.backend_dispatches, 1);
        assert_eq!(snap.backend_words, 9);
        assert!((snap.coalesce_rate() - 0.25).abs() < 1e-9);
        assert!(snap.p50_us > 0);
        let line = format!("{snap}");
        assert!(line.contains("breaker[opened=1 half_opened=1 closed=1]"), "{line}");
        assert!(line.contains("shed[rate_limited=2 overloaded=0]"), "{line}");
        assert!(line.contains("unavailable=5"), "{line}");
    }

    #[test]
    fn snapshot_saturation_counters_roundtrip() {
        let s = ServiceMetrics::new();
        s.queue_full_events.fetch_add(3, Ordering::Relaxed); // ord: Relaxed — stats
        s.slab_waits.fetch_add(2, Ordering::Relaxed); // ord: Relaxed — stats
        let snap = s.snapshot();
        assert_eq!(snap.queue_full_events, 3);
        assert_eq!(snap.slab_waits, 2);
        let line = format!("{snap}");
        assert!(line.contains("queue_full=3"), "{line}");
        assert!(line.contains("slab_waits=2"), "{line}");
    }

    #[test]
    fn per_algorithm_word_counters() {
        use crate::analysis::Algorithm;
        let s = ServiceMetrics::new();
        s.record_algorithm_words(Algorithm::Khoja, 7);
        s.record_algorithm_words(Algorithm::Khoja, 3);
        s.record_algorithm_words(Algorithm::Light, 2);
        let snap = s.snapshot();
        assert_eq!(snap.algo_words[Algorithm::Khoja as usize], 10);
        assert_eq!(snap.algo_words[Algorithm::Light as usize], 2);
        assert_eq!(snap.algo_words[Algorithm::Linguistic as usize], 0);
    }

    #[test]
    fn prometheus_text_renders_required_series() {
        use crate::analysis::Algorithm;
        let s = ServiceMetrics::new();
        s.record_batch(12);
        s.record_latency(Duration::from_micros(100));
        s.cache_hits.fetch_add(3, Ordering::Relaxed); // ord: Relaxed — stats
        s.cache_misses.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
        s.record_algorithm_words(Algorithm::Voting, 12);
        let g = GatewayMetrics::new();
        g.record_envelope(5);
        g.record_latency(Duration::from_micros(50));
        let mut page = PromText::new();
        s.render_prometheus(&mut page);
        g.render_prometheus(&mut page);
        let text = page.finish();
        // the series verify.sh greps for
        assert!(text.contains("ama_requests_total 1"), "{text}");
        assert!(text.contains("ama_cache_hit_rate 0.750000"), "{text}");
        // per-algorithm labels
        assert!(text.contains("ama_algorithm_words_total{algorithm=\"voting\"} 12"), "{text}");
        assert!(text.contains("ama_algorithm_words_total{algorithm=\"khoja\"} 0"), "{text}");
        // histogram shape: cumulative buckets, +Inf closes the series
        assert!(text.contains("ama_request_latency_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("ama_request_latency_seconds_count 1"), "{text}");
        // gateway series present on the same page
        assert!(text.contains("ama_gateway_envelopes_total 1"), "{text}");
        assert!(text.contains("ama_gateway_request_latency_seconds_bucket"), "{text}");
        // every HELP has a TYPE
        let helps = text.matches("# HELP").count();
        let types = text.matches("# TYPE").count();
        assert_eq!(helps, types, "{text}");
    }

    #[test]
    fn metrics_http_endpoint_serves_prometheus_text() {
        let s = Arc::new(ServiceMetrics::new());
        s.record_batch(4);
        s.record_latency(Duration::from_micros(10));
        let render_src = s.clone();
        let server = MetricsServer::start(
            "127.0.0.1:0",
            Arc::new(move || {
                let mut page = PromText::new();
                render_src.render_prometheus(&mut page);
                page.finish()
            }),
        )
        .unwrap();
        let addr = server.local_addr();

        let scrape = |path: &str| -> String {
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.write_all(format!("GET {path} HTTP/1.0\r\nHost: x\r\n\r\n").as_bytes()).unwrap();
            let mut out = String::new();
            conn.read_to_string(&mut out).unwrap();
            out
        };
        let resp = scrape("/metrics");
        assert!(resp.starts_with("HTTP/1.0 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"), "{resp}");
        assert!(resp.contains("ama_requests_total 1"), "{resp}");
        assert!(resp.contains("ama_words_total 4"), "{resp}");
        let missing = scrape("/nope");
        assert!(missing.starts_with("HTTP/1.0 404"), "{missing}");

        server.stop(); // joins the scrape thread; no panic ⇒ clean drain
    }
}
