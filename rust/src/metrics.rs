//! Performance metric substrate — the paper's §6.2 metric set (ET, TH) for
//! software plus latency histograms for the serving path. The hardware-only
//! metrics (PD, LUT, LR, PC) live in [`crate::hw::area`].
//!
//! The serving-path metrics are built on [`LatencyHistogram`], a lock-free
//! log₂-bucketed microsecond histogram: one atomic increment per sample,
//! percentiles read from bucket upper bounds. [`ServiceMetrics`] bundles it
//! with request/batch/saturation counters; the same histogram type is
//! reused standalone by the `ama loadtest` client fleet for client-side
//! round-trip latency.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Execution-time / throughput measurement of a finished run.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub words: u64,
    pub elapsed: Duration,
}

impl Measurement {
    /// TH, in words per second (the paper's Wps).
    pub fn wps(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.words as f64 / self.elapsed.as_secs_f64()
    }
}

/// Time a closure over a word count.
pub fn measure<F: FnOnce()>(words: u64, f: F) -> Measurement {
    let start = Instant::now();
    f();
    Measurement { words, elapsed: start.elapsed() }
}

/// Number of log₂ microsecond buckets: bucket `i` counts samples in
/// `[2^i, 2^(i+1))` µs, with the last bucket absorbing everything larger
/// (≈ 2 s and up).
pub const LATENCY_BUCKETS: usize = 21;

/// Lock-free log₂-bucketed latency histogram (microsecond resolution).
///
/// Recording is one relaxed atomic increment; percentile reads return the
/// upper bound of the bucket containing the requested quantile (i.e. a
/// ≤2× overestimate, which is the right bias for tail-latency reporting).
#[derive(Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().max(1) as u64);
    }

    pub fn record_us(&self, us: u64) {
        let us = us.max(1);
        let bucket = (63 - us.leading_zeros() as usize).min(LATENCY_BUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Per-bucket counts (index `i` covers `[2^i, 2^(i+1))` µs).
    pub fn counts(&self) -> [u64; LATENCY_BUCKETS] {
        let mut out = [0u64; LATENCY_BUCKETS];
        for (o, b) in out.iter_mut().zip(&self.buckets) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    pub fn total(&self) -> u64 {
        self.counts().iter().sum()
    }

    /// Approximate latency percentile, in µs (upper bucket bound);
    /// 0 when the histogram is empty.
    pub fn percentile_us(&self, q: f64) -> u64 {
        let counts = self.counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << LATENCY_BUCKETS
    }
}

/// Lock-free service counters shared across coordinator threads.
#[derive(Default)]
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub words: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Total words across batches, for mean batch-size accounting.
    pub batched_words: AtomicU64,
    /// Saturation counter: submissions that found the request queue full
    /// and had to block (backpressure engaged).
    pub queue_full_events: AtomicU64,
    /// Saturation counter: submissions that found the reply slab exhausted
    /// (every reply slot in flight) and had to wait for capacity.
    pub slab_waits: AtomicU64,
    /// Typed rejections (PR 3): requests answered with the protocol error
    /// `QUEUE_FULL` (request queue stayed full past the submit deadline).
    pub rejected_queue_full: AtomicU64,
    /// Typed rejections: requests answered with `SHUTDOWN` (coordinator
    /// closed before or during submission).
    pub rejected_shutdown: AtomicU64,
    /// Typed rejections: envelopes answered with `BAD_WORD` (empty or
    /// non-Arabic word in an AMA/1 batch).
    pub rejected_bad_word: AtomicU64,
    /// Stem-cache probes answered from the cache (PR 4): requests that
    /// never reached a kernel. Counted by the cache-fronted
    /// `RegistryBackend`; zero when serving without a cache.
    pub cache_hits: AtomicU64,
    /// Stem-cache probes that fell through to kernel dispatch (and then
    /// seeded the cache).
    pub cache_misses: AtomicU64,
    /// Histogram of request latency (submit → reply fill).
    latency: LatencyHistogram,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, words: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_words.fetch_add(words, Ordering::Relaxed);
        self.words.fetch_add(words, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        self.latency.record(d);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    /// The request-latency histogram (shared shape with client-side
    /// histograms in the load harness).
    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_words.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency percentile from the log2 histogram, in µs
    /// (upper bucket bound).
    pub fn latency_percentile_us(&self, q: f64) -> u64 {
        self.latency.percentile_us(q)
    }

    /// Count a typed protocol rejection (`None` for codes without a
    /// dedicated counter — they still show up in `errors` where counted
    /// by the caller).
    pub fn record_rejection(&self, code: crate::analysis::ErrorCode) {
        use crate::analysis::ErrorCode;
        match code {
            ErrorCode::QueueFull => &self.rejected_queue_full,
            ErrorCode::Shutdown => &self.rejected_shutdown,
            ErrorCode::BadWord => &self.rejected_bad_word,
            _ => return,
        }
        .fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            words: self.words.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            queue_full_events: self.queue_full_events.load(Ordering::Relaxed),
            slab_waits: self.slab_waits.load(Ordering::Relaxed),
            rejected_queue_full: self.rejected_queue_full.load(Ordering::Relaxed),
            rejected_shutdown: self.rejected_shutdown.load(Ordering::Relaxed),
            rejected_bad_word: self.rejected_bad_word.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            mean_batch_size: self.mean_batch_size(),
            p50_us: self.latency.percentile_us(0.50),
            p90_us: self.latency.percentile_us(0.90),
            p99_us: self.latency.percentile_us(0.99),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub words: u64,
    pub batches: u64,
    pub errors: u64,
    pub queue_full_events: u64,
    pub slab_waits: u64,
    pub rejected_queue_full: u64,
    pub rejected_shutdown: u64,
    pub rejected_bad_word: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub mean_batch_size: f64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
}

impl MetricsSnapshot {
    /// Fraction of cache probes that hit (0.0 with no probes — i.e. no
    /// cache configured or nothing served yet).
    pub fn cache_hit_rate(&self) -> f64 {
        let probes = self.cache_hits + self.cache_misses;
        if probes == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / probes as f64
    }
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} words={} batches={} mean_batch={:.1} p50={}us p90={}us p99={}us \
             queue_full={} slab_waits={} errors={} \
             rejected[queue_full={} shutdown={} bad_word={}] \
             cache[hits={} misses={} rate={:.3}]",
            self.requests,
            self.words,
            self.batches,
            self.mean_batch_size,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.queue_full_events,
            self.slab_waits,
            self.errors,
            self.rejected_queue_full,
            self.rejected_shutdown,
            self.rejected_bad_word,
            self.cache_hits,
            self.cache_misses,
            self.cache_hit_rate()
        )
    }
}

/// Lock-free gateway-tier counters (PR 7): one instance per running
/// gateway, shared across its connection handlers and the health prober.
/// Everything is a relaxed atomic increment on the hot path; the chaos
/// harness reads a [`GatewaySnapshot`] to assert breaker transitions and
/// loss-free failover.
#[derive(Default)]
pub struct GatewayMetrics {
    /// AMA/1 envelopes accepted on the front side.
    pub envelopes: AtomicU64,
    /// Words carried by those envelopes.
    pub words: AtomicU64,
    /// Backend dispatch groups actually sent to replicas (after sharding
    /// and coalescing collapse).
    pub backend_dispatches: AtomicU64,
    /// Words sent to replicas. `words - backend_words` is the coalescing
    /// + dedup savings.
    pub backend_words: AtomicU64,
    /// Words answered by piggybacking on an identical in-flight dispatch
    /// (never reached a replica).
    pub coalesced_words: AtomicU64,
    /// Backend attempts beyond the first for a dispatch group (backoff
    /// retries on the same endpoint).
    pub retries: AtomicU64,
    /// Dispatch groups rerouted to a different replica after their shard
    /// owner failed.
    pub failovers: AtomicU64,
    /// Breaker transitions closed→open (trip).
    pub breaker_opened: AtomicU64,
    /// Breaker transitions open→half-open (cooldown expired, trial
    /// request admitted).
    pub breaker_half_opened: AtomicU64,
    /// Breaker transitions half-open→closed (trial succeeded; replica
    /// recovered).
    pub breaker_closed: AtomicU64,
    /// Front-side requests shed by the per-client token bucket
    /// (`RATE_LIMITED`).
    pub shed_rate_limited: AtomicU64,
    /// Front-side requests shed by the per-client in-flight cap
    /// (`RATE_LIMITED` with retry-after, no token consumed).
    pub shed_overloaded: AtomicU64,
    /// Requests answered `UNAVAILABLE` (no healthy replica within the
    /// retry/deadline budget).
    pub unavailable: AtomicU64,
    /// Background health-probe failures (prober-side view of outages).
    pub probe_failures: AtomicU64,
    /// Front-side request latency (envelope read → reply written).
    latency: LatencyHistogram,
}

impl GatewayMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_envelope(&self, words: u64) {
        self.envelopes.fetch_add(1, Ordering::Relaxed);
        self.words.fetch_add(words, Ordering::Relaxed);
    }

    pub fn record_dispatch(&self, words: u64) {
        self.backend_dispatches.fetch_add(1, Ordering::Relaxed);
        self.backend_words.fetch_add(words, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        self.latency.record(d);
    }

    pub fn latency(&self) -> &LatencyHistogram {
        &self.latency
    }

    pub fn snapshot(&self) -> GatewaySnapshot {
        GatewaySnapshot {
            envelopes: self.envelopes.load(Ordering::Relaxed),
            words: self.words.load(Ordering::Relaxed),
            backend_dispatches: self.backend_dispatches.load(Ordering::Relaxed),
            backend_words: self.backend_words.load(Ordering::Relaxed),
            coalesced_words: self.coalesced_words.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            breaker_opened: self.breaker_opened.load(Ordering::Relaxed),
            breaker_half_opened: self.breaker_half_opened.load(Ordering::Relaxed),
            breaker_closed: self.breaker_closed.load(Ordering::Relaxed),
            shed_rate_limited: self.shed_rate_limited.load(Ordering::Relaxed),
            shed_overloaded: self.shed_overloaded.load(Ordering::Relaxed),
            unavailable: self.unavailable.load(Ordering::Relaxed),
            probe_failures: self.probe_failures.load(Ordering::Relaxed),
            p50_us: self.latency.percentile_us(0.50),
            p90_us: self.latency.percentile_us(0.90),
            p99_us: self.latency.percentile_us(0.99),
        }
    }
}

#[derive(Clone, Copy, Debug, Default)]
pub struct GatewaySnapshot {
    pub envelopes: u64,
    pub words: u64,
    pub backend_dispatches: u64,
    pub backend_words: u64,
    pub coalesced_words: u64,
    pub retries: u64,
    pub failovers: u64,
    pub breaker_opened: u64,
    pub breaker_half_opened: u64,
    pub breaker_closed: u64,
    pub shed_rate_limited: u64,
    pub shed_overloaded: u64,
    pub unavailable: u64,
    pub probe_failures: u64,
    pub p50_us: u64,
    pub p90_us: u64,
    pub p99_us: u64,
}

impl GatewaySnapshot {
    /// Fraction of front-side words that never cost a backend dispatch
    /// (coalesced onto an identical in-flight request).
    pub fn coalesce_rate(&self) -> f64 {
        if self.words == 0 {
            return 0.0;
        }
        self.coalesced_words as f64 / self.words as f64
    }
}

impl std::fmt::Display for GatewaySnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "envelopes={} words={} p50={}us p90={}us p99={}us \
             backend[dispatches={} words={}] coalesced={} ({:.3}) \
             retries={} failovers={} \
             breaker[opened={} half_opened={} closed={}] \
             shed[rate_limited={} overloaded={}] unavailable={} probe_failures={}",
            self.envelopes,
            self.words,
            self.p50_us,
            self.p90_us,
            self.p99_us,
            self.backend_dispatches,
            self.backend_words,
            self.coalesced_words,
            self.coalesce_rate(),
            self.retries,
            self.failovers,
            self.breaker_opened,
            self.breaker_half_opened,
            self.breaker_closed,
            self.shed_rate_limited,
            self.shed_overloaded,
            self.unavailable,
            self.probe_failures
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wps_computation() {
        let m = Measurement { words: 1000, elapsed: Duration::from_millis(500) };
        assert!((m.wps() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn zero_elapsed_guard() {
        let m = Measurement { words: 10, elapsed: Duration::ZERO };
        assert_eq!(m.wps(), 0.0);
    }

    #[test]
    fn latency_histogram_percentiles() {
        let s = ServiceMetrics::new();
        for _ in 0..99 {
            s.record_latency(Duration::from_micros(100)); // bucket ~6
        }
        s.record_latency(Duration::from_millis(10)); // bucket ~13
        let p50 = s.latency_percentile_us(0.5);
        let p99 = s.latency_percentile_us(0.99);
        assert!(p50 <= 256, "p50 {p50}");
        assert!(p99 <= 256, "p99 {p99}"); // 99th of 100 is still the fast bucket
        let p100 = s.latency_percentile_us(1.0);
        assert!(p100 >= 8192, "p100 {p100}");
    }

    #[test]
    fn standalone_histogram() {
        let h = LatencyHistogram::new();
        assert_eq!(h.percentile_us(0.5), 0); // empty
        h.record_us(3);
        h.record_us(3_000_000); // past the last bucket bound (2^21 µs)
        assert_eq!(h.total(), 2);
        assert!(h.percentile_us(0.5) <= 4);
        assert!(h.percentile_us(1.0) >= 1 << 20);
        // the last bucket absorbs out-of-range samples
        h.record_us(u64::MAX);
        assert_eq!(h.counts()[LATENCY_BUCKETS - 1], 2);
    }

    #[test]
    fn batch_accounting() {
        let s = ServiceMetrics::new();
        s.record_batch(10);
        s.record_batch(30);
        assert_eq!(s.mean_batch_size(), 20.0);
        assert_eq!(s.snapshot().words, 40);
    }

    #[test]
    fn rejection_counters_roundtrip() {
        use crate::analysis::ErrorCode;
        let s = ServiceMetrics::new();
        s.record_rejection(ErrorCode::QueueFull);
        s.record_rejection(ErrorCode::Shutdown);
        s.record_rejection(ErrorCode::Shutdown);
        s.record_rejection(ErrorCode::BadWord);
        s.record_rejection(ErrorCode::Timeout); // no dedicated counter
        let snap = s.snapshot();
        assert_eq!(snap.rejected_queue_full, 1);
        assert_eq!(snap.rejected_shutdown, 2);
        assert_eq!(snap.rejected_bad_word, 1);
        let line = format!("{snap}");
        assert!(line.contains("rejected[queue_full=1 shutdown=2 bad_word=1]"), "{line}");
    }

    #[test]
    fn cache_counters_and_hit_rate() {
        let s = ServiceMetrics::new();
        assert_eq!(s.snapshot().cache_hit_rate(), 0.0, "no probes → 0.0");
        s.cache_hits.fetch_add(3, Ordering::Relaxed);
        s.cache_misses.fetch_add(1, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.cache_hits, 3);
        assert_eq!(snap.cache_misses, 1);
        assert!((snap.cache_hit_rate() - 0.75).abs() < 1e-9);
        let line = format!("{snap}");
        assert!(line.contains("cache[hits=3 misses=1 rate=0.750]"), "{line}");
    }

    #[test]
    fn gateway_counters_and_display() {
        let g = GatewayMetrics::new();
        g.record_envelope(8);
        g.record_envelope(4);
        g.record_dispatch(9);
        g.coalesced_words.fetch_add(3, Ordering::Relaxed);
        g.breaker_opened.fetch_add(1, Ordering::Relaxed);
        g.breaker_half_opened.fetch_add(1, Ordering::Relaxed);
        g.breaker_closed.fetch_add(1, Ordering::Relaxed);
        g.shed_rate_limited.fetch_add(2, Ordering::Relaxed);
        g.unavailable.fetch_add(5, Ordering::Relaxed);
        g.record_latency(Duration::from_micros(100));
        let snap = g.snapshot();
        assert_eq!(snap.envelopes, 2);
        assert_eq!(snap.words, 12);
        assert_eq!(snap.backend_dispatches, 1);
        assert_eq!(snap.backend_words, 9);
        assert!((snap.coalesce_rate() - 0.25).abs() < 1e-9);
        assert!(snap.p50_us > 0);
        let line = format!("{snap}");
        assert!(line.contains("breaker[opened=1 half_opened=1 closed=1]"), "{line}");
        assert!(line.contains("shed[rate_limited=2 overloaded=0]"), "{line}");
        assert!(line.contains("unavailable=5"), "{line}");
    }

    #[test]
    fn snapshot_saturation_counters_roundtrip() {
        let s = ServiceMetrics::new();
        s.queue_full_events.fetch_add(3, Ordering::Relaxed);
        s.slab_waits.fetch_add(2, Ordering::Relaxed);
        let snap = s.snapshot();
        assert_eq!(snap.queue_full_events, 3);
        assert_eq!(snap.slab_waits, 2);
        let line = format!("{snap}");
        assert!(line.contains("queue_full=3"), "{line}");
        assert!(line.contains("slab_waits=2"), "{line}");
    }
}
