//! Performance metric substrate — the paper's §6.2 metric set (ET, TH) for
//! software plus latency histograms for the serving path. The hardware-only
//! metrics (PD, LUT, LR, PC) live in [`crate::hw::area`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Execution-time / throughput measurement of a finished run.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub words: u64,
    pub elapsed: Duration,
}

impl Measurement {
    /// TH, in words per second (the paper's Wps).
    pub fn wps(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.words as f64 / self.elapsed.as_secs_f64()
    }
}

/// Time a closure over a word count.
pub fn measure<F: FnOnce()>(words: u64, f: F) -> Measurement {
    let start = Instant::now();
    f();
    Measurement { words, elapsed: start.elapsed() }
}

/// Lock-free service counters shared across coordinator threads.
#[derive(Default)]
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub words: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Total words across batches, for mean batch-size accounting.
    pub batched_words: AtomicU64,
    /// Histogram of request latency (log2 microsecond buckets 0..=20).
    latency_buckets: [AtomicU64; 21],
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, words: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_words.fetch_add(words, Ordering::Relaxed);
        self.words.fetch_add(words, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(20);
        self.latency_buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_words.load(Ordering::Relaxed) as f64 / b as f64
    }

    /// Approximate latency percentile from the log2 histogram, in µs
    /// (upper bucket bound).
    pub fn latency_percentile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> =
            self.latency_buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (total as f64 * q).ceil() as u64;
        let mut acc = 0;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 21
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            words: self.words.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            mean_batch_size: self.mean_batch_size(),
            p50_us: self.latency_percentile_us(0.50),
            p99_us: self.latency_percentile_us(0.99),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub words: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_batch_size: f64,
    pub p50_us: u64,
    pub p99_us: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} words={} batches={} mean_batch={:.1} p50={}us p99={}us errors={}",
            self.requests,
            self.words,
            self.batches,
            self.mean_batch_size,
            self.p50_us,
            self.p99_us,
            self.errors
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wps_computation() {
        let m = Measurement { words: 1000, elapsed: Duration::from_millis(500) };
        assert!((m.wps() - 2000.0).abs() < 1e-9);
    }

    #[test]
    fn zero_elapsed_guard() {
        let m = Measurement { words: 10, elapsed: Duration::ZERO };
        assert_eq!(m.wps(), 0.0);
    }

    #[test]
    fn latency_histogram_percentiles() {
        let s = ServiceMetrics::new();
        for _ in 0..99 {
            s.record_latency(Duration::from_micros(100)); // bucket ~6
        }
        s.record_latency(Duration::from_millis(10)); // bucket ~13
        let p50 = s.latency_percentile_us(0.5);
        let p99 = s.latency_percentile_us(0.99);
        assert!(p50 <= 256, "p50 {p50}");
        assert!(p99 <= 256, "p99 {p99}"); // 99th of 100 is still the fast bucket
        let p100 = s.latency_percentile_us(1.0);
        assert!(p100 >= 8192, "p100 {p100}");
    }

    #[test]
    fn batch_accounting() {
        let s = ServiceMetrics::new();
        s.record_batch(10);
        s.record_batch(30);
        assert_eq!(s.mean_batch_size(), 20.0);
        assert_eq!(s.snapshot().words, 40);
    }
}
