//! `ama` — the leader binary: CLI over the full stack (DESIGN.md §3).

use ama::analysis::{Algorithm, AnalyzeOptions, Analyzer as _, AnalyzerRegistry};
use ama::chars::ArabicWord;
use ama::cli::{Args, USAGE};
use ama::coordinator::{
    BackendFactory, Coordinator, CoordinatorConfig, HwBackend, RegistryBackend, RuntimeBackend,
    SoftwareBackend, StemBackend,
};
use ama::corpus::{self, CorpusConfig};
use ama::hw::{DatapathConfig, NonPipelinedProcessor, PipelinedProcessor};
use ama::khoja::KhojaStemmer;
use ama::roots::RootSet;
use ama::runtime::Engine;
use ama::stemmer::{Stemmer, StemmerConfig};
use ama::{eval, report};
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

fn run(argv: Vec<String>) -> Result<()> {
    let args = Args::parse(argv).map_err(|e| anyhow!(e))?;
    let Some(cmd) = args.positionals.first().cloned() else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd.as_str() {
        "stem" => cmd_stem(&args),
        "corpus" => cmd_corpus(&args),
        "analyze" => cmd_analyze(&args),
        "simulate" => cmd_simulate(&args),
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "loadtest" => cmd_loadtest(&args),
        "selftest" => cmd_selftest(&args),
        "bench" => cmd_bench(&args),
        "emit-hlo" => cmd_emit_hlo(&args),
        "gateway" => cmd_gateway(&args),
        "gateway-loadtest" => cmd_gateway_loadtest(&args),
        "index" => cmd_index(&args),
        "search" => cmd_search(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn data_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.flag_or("--data-dir", "data"))
}

fn artifacts_dir(args: &Args) -> PathBuf {
    args.flag("--artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ama::runtime::default_artifacts_dir)
}

fn load_roots(args: &Args) -> Result<Arc<RootSet>> {
    let dir = data_dir(args);
    if dir.join("roots_trilateral.txt").exists() {
        Ok(Arc::new(RootSet::load(&dir)?))
    } else {
        eprintln!(
            "note: {} has no dictionaries (run `make data`); using the built-in mini dictionary",
            dir.display()
        );
        Ok(Arc::new(RootSet::builtin_mini()))
    }
}

/// Build a backend factory by name. `coord_workers` is the number of
/// coordinator workers that will share the machine — intra-batch
/// parallelism divides the cores among them instead of oversubscribing.
fn backend_factory(
    name: &str,
    roots: Arc<RootSet>,
    infix: bool,
    artifacts: PathBuf,
    coord_workers: usize,
) -> Result<BackendFactory> {
    let cfg = StemmerConfig { infix_processing: infix };
    let hw_cfg = DatapathConfig { infix_units: infix };
    Ok(match name {
        "registry" => Box::new(move |_| {
            Ok(Box::new(RegistryBackend::with_config(roots.clone(), cfg)))
        }),
        "software" => Box::new(move |_| {
            Ok(Box::new(SoftwareBackend(Stemmer::new(roots.clone(), cfg))))
        }),
        "software-par" => Box::new(move |_| {
            let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
            let threads = (cores / coord_workers.max(1)).max(1);
            Ok(Box::new(ama::coordinator::ParallelSoftwareBackend {
                stemmer: Stemmer::new(roots.clone(), cfg),
                threads,
            }))
        }),
        "khoja" => Box::new(move |_| {
            struct K(KhojaStemmer);
            impl StemBackend for K {
                fn name(&self) -> &'static str {
                    "khoja"
                }
                fn algorithm(&self) -> Algorithm {
                    Algorithm::Khoja
                }
                fn stem_batch(
                    &mut self,
                    w: &[ArabicWord],
                ) -> Result<Vec<ama::stemmer::StemResult>> {
                    Ok(self.0.stem_batch(w))
                }
            }
            Ok(Box::new(K(KhojaStemmer::new(roots.clone()))))
        }),
        "hw-np" => Box::new(move |_| {
            Ok(Box::new(HwBackend(NonPipelinedProcessor::new(roots.clone(), hw_cfg))))
        }),
        "hw-p" => Box::new(move |_| {
            Ok(Box::new(HwBackend(PipelinedProcessor::new(roots.clone(), hw_cfg))))
        }),
        // `xla` kept as an alias for the pre-PR-5 CLI surface.
        "runtime" | "xla" => Box::new(move |_| {
            let engine = Engine::load(&artifacts, &roots)
                .context("loading runtime engine (run `make artifacts`?)")?;
            Ok(Box::new(RuntimeBackend(engine)))
        }),
        other => bail!(
            "unknown backend {other:?} (registry|software|software-par|khoja|hw-np|hw-p|runtime)"
        ),
    })
}

fn cmd_stem(args: &Args) -> Result<()> {
    let words: Vec<ArabicWord> =
        args.positionals[1..].iter().map(|s| ArabicWord::encode(s)).collect();
    if words.is_empty() {
        bail!("usage: ama stem <words…>");
    }
    let roots = load_roots(args)?;
    let infix = !args.switch("--no-infix");
    let factory = backend_factory(
        args.flag_or("--backend", "software"),
        roots,
        infix,
        artifacts_dir(args),
        CoordinatorConfig::default().workers,
    )?;
    let coord = Coordinator::start(CoordinatorConfig::default(), factory);
    let handle = coord.handle();
    let results = handle.stem_stream(&words)?;
    for (w, r) in args.positionals[1..].iter().zip(results) {
        println!(
            "{w}\t{}\t{:?}\tcut={}",
            r.root_word().to_string_ar(),
            r.kind,
            r.cut
        );
    }
    coord.shutdown();
    Ok(())
}

fn cmd_corpus(args: &Args) -> Result<()> {
    let roots = load_roots(args)?;
    let cfg = if args.switch("--quran") {
        CorpusConfig::quran()
    } else if args.switch("--ankabut") {
        CorpusConfig::ankabut()
    } else {
        CorpusConfig::small(
            args.flag_usize("--words", 10_000).map_err(|e| anyhow!(e))?,
            args.flag_u64("--seed", 1).map_err(|e| anyhow!(e))?,
        )
    };
    let c = corpus::generate(&roots, &cfg);
    println!("{}", report::corpus_stats_line(&c));
    if let Some(out) = args.flag("--out") {
        corpus::write_tsv(&c, Path::new(out))?;
        println!("wrote {out}");
    }
    Ok(())
}

/// `ama analyze <words…>` — the unified analyzer API from the command
/// line: any engine, per-request infix override, optional 5-stage trace,
/// either locally (in-process registry) or against a running server over
/// AMA/1 (`--connect host:port`).
fn cmd_analyze_words(args: &Args) -> Result<()> {
    let algorithm = match args.flag("--algo") {
        None => Algorithm::Linguistic,
        Some(name) => Algorithm::from_name(name)
            .ok_or_else(|| anyhow!("unknown --algo {name:?} (linguistic|khoja|light|voting)"))?,
    };
    let opts = AnalyzeOptions {
        algorithm,
        infix: if args.switch("--no-infix") { Some(false) } else { None },
        want_trace: args.switch("--trace"),
    };
    let words = &args.positionals[1..];

    let print_result = |word: &str, r: &ama::protocol::WireResult| {
        println!(
            "{word}\t{}\t{:?}\tcut={}\talgo={}\tconfidence={:.2}\tvotes={}",
            if r.root.is_empty() { "-" } else { &r.root },
            r.kind,
            r.cut,
            r.algo,
            r.confidence,
            r.votes
        );
        if let Some(trace) = &r.trace {
            for (stage, detail) in trace {
                println!("    [{stage:>10}] {detail}");
            }
        }
    };

    if let Some(addr) = args.flag("--connect") {
        use std::net::ToSocketAddrs as _;
        let addr = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving {addr}"))?
            .next()
            .ok_or_else(|| anyhow!("{addr} resolved to no address"))?;
        let mut client = ama::client::Client::connect(addr)?;
        let refs: Vec<&str> = words.iter().map(String::as_str).collect();
        let results = client.analyze(&refs, &opts)?;
        for (w, r) in words.iter().zip(&results) {
            print_result(w, r);
        }
        return Ok(());
    }

    let registry = AnalyzerRegistry::new(load_roots(args)?);
    for w in words {
        let a = registry.analyze(&ArabicWord::encode(w), &opts);
        print_result(w, &ama::protocol::WireResult::from_analysis(w, &a));
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    if args.positionals.len() > 1 {
        return cmd_analyze_words(args);
    }
    let roots = load_roots(args)?;
    let which = args.flag_or("--corpus", "quran");
    let c = match which {
        "quran" => corpus::generate(&roots, &CorpusConfig::quran()),
        "ankabut" => corpus::generate(&roots, &CorpusConfig::ankabut()),
        path => corpus::read_tsv(Path::new(path))?,
    };
    println!("{}", report::corpus_stats_line(&c));
    let infix = !args.switch("--no-infix");
    let stemmer = Stemmer::new(roots.clone(), StemmerConfig { infix_processing: infix });
    let rep = eval::evaluate(&c, if infix { "with-infix" } else { "no-infix" }, |ws| {
        stemmer.stem_batch(ws)
    });
    println!(
        "{}: roots {}/{} = {:.1}%  words {}/{} = {:.1}%",
        rep.stemmer,
        rep.roots_recovered,
        rep.roots_present,
        100.0 * rep.root_accuracy(),
        rep.words_correct,
        rep.words_total,
        100.0 * rep.word_accuracy()
    );
    if args.switch("--khoja") {
        let kh = KhojaStemmer::new(roots.clone());
        let rep = eval::evaluate(&c, "khoja", |ws| kh.stem_batch(ws));
        println!(
            "khoja: roots {}/{} = {:.1}%  words {}/{} = {:.1}%",
            rep.roots_recovered,
            rep.roots_present,
            100.0 * rep.root_accuracy(),
            rep.words_correct,
            rep.words_total,
            100.0 * rep.word_accuracy()
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let roots = load_roots(args)?;
    if args.switch("--trace") || args.flag("--words").is_none() {
        print!("{}", report::figure_traces(&roots));
        return Ok(());
    }
    let n = args.flag_usize("--words", 1000).map_err(|e| anyhow!(e))?;
    let c = corpus::generate(&roots, &CorpusConfig::small(n, 42));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();
    let cfg = DatapathConfig { infix_units: !args.switch("--no-infix") };
    use ama::hw::Processor as _;
    match args.flag_or("--processor", "pipelined") {
        "pipelined" => {
            let mut p = PipelinedProcessor::new(roots, cfg);
            let (_, stats) = p.run(&words);
            println!(
                "pipelined: {} words in {} cycles @ {:.2} MHz -> {:.2} MWps (model)",
                stats.words,
                stats.cycles,
                p.fmax_mhz(),
                p.throughput_wps(stats.words) / 1e6
            );
        }
        "non-pipelined" => {
            let mut p = NonPipelinedProcessor::new(roots, cfg);
            let (_, stats) = p.run(&words);
            println!(
                "non-pipelined: {} words in {} cycles @ {:.2} MHz -> {:.2} MWps (model)",
                stats.words,
                stats.cycles,
                p.fmax_mhz(),
                p.throughput_wps(stats.words) / 1e6
            );
        }
        other => bail!("unknown processor {other:?}"),
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let roots = load_roots(args)?;
    if let Some(table) = args.flag("--table") {
        match table {
            "morphology" => print!("{}", report::table_morphology()),
            "truncation" => print!("{}", report::table_truncation(&roots)),
            "hw" => print!("{}", report::table_hw()),
            "ratios" => print!("{}", report::table_ratios(&roots)),
            "accuracy" => {
                let (q, a) = report::standard_corpora(&roots);
                print!("{}", report::table_accuracy(&roots, &q, &a));
            }
            "roots" => {
                let (q, _) = report::standard_corpora(&roots);
                print!("{}", report::table_roots(&roots, &q));
            }
            "analyzers" => {
                let (_, a) = report::standard_corpora(&roots);
                print!("{}", report::table_analyzers(&roots, &a));
            }
            other => bail!("unknown table {other:?}"),
        }
        return Ok(());
    }
    if let Some(figure) = args.flag("--figure") {
        match figure {
            "throughput" => {
                let (q, _) = report::standard_corpora(&roots);
                print!("{}", report::figure_throughput(&roots, &q, None));
            }
            "sweep" => print!("{}", report::figure_sweep(&roots)),
            "traces" => print!("{}", report::figure_traces(&roots)),
            other => bail!("unknown figure {other:?}"),
        }
        return Ok(());
    }
    // default: everything
    let (q, a) = report::standard_corpora(&roots);
    println!("{}", report::corpus_stats_line(&q));
    println!("{}", report::corpus_stats_line(&a));
    print!("{}", report::table_morphology());
    print!("{}", report::table_truncation(&roots));
    print!("{}", report::table_hw());
    print!("{}", report::table_ratios(&roots));
    print!("{}", report::table_accuracy(&roots, &q, &a));
    print!("{}", report::table_roots(&roots, &q));
    print!("{}", report::table_analyzers(&roots, &a));
    print!("{}", report::figure_throughput(&roots, &q, None));
    print!("{}", report::figure_sweep(&roots));
    Ok(())
}

/// Start a coordinator for a named backend. The `registry` backend goes
/// through [`Coordinator::start_registry_cached`] so the shared stem
/// cache (sized by `--cache-slots`, 0 = off) fronts kernel dispatch and
/// its hit/miss counters land in the coordinator's metrics; every other
/// backend uses the generic factory path, cache-less.
fn start_coordinator(
    args: &Args,
    backend: &str,
    roots: Arc<RootSet>,
    infix: bool,
    cfg: CoordinatorConfig,
) -> Result<Coordinator> {
    if backend == "registry" {
        let cache_slots = args
            .flag_usize("--cache-slots", ama::cache::DEFAULT_CACHE_SLOTS)
            .map_err(|e| anyhow!(e))?;
        return Ok(Coordinator::start_registry_cached(
            cfg,
            roots,
            StemmerConfig { infix_processing: infix },
            cache_slots,
        ));
    }
    let factory = backend_factory(backend, roots, infix, artifacts_dir(args), cfg.workers)?;
    Ok(Coordinator::start(cfg, factory))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let roots = load_roots(args)?;
    let workers = args.flag_usize("--workers", 1).map_err(|e| anyhow!(e))?;
    // Default backend is the PR-3 registry: one process answers
    // per-request algorithm/infix/trace for all four engines, and the
    // legacy bare-line protocol behaves exactly like the old `software`
    // backend (default options select the linguistic engine).
    let backend = args.flag_or("--backend", "registry");
    let cfg = CoordinatorConfig {
        workers,
        max_batch: args.flag_usize("--batch", 256).map_err(|e| anyhow!(e))?,
        max_wait: Duration::from_micros(
            args.flag_u64("--max-wait-us", 2000).map_err(|e| anyhow!(e))?,
        ),
        ..Default::default()
    };
    let coord = start_coordinator(args, backend, roots, !args.switch("--no-infix"), cfg)?;
    let port = args.flag_usize("--port", 7601).map_err(|e| anyhow!(e))?;
    let srv_cfg = ama::server::ServerConfig {
        handlers: args.flag_usize("--handlers", 8).map_err(|e| anyhow!(e))?,
        event_loop: parse_event_loop(args)?,
        loops: args.flag_usize("--loops", 0).map_err(|e| anyhow!(e))?,
        ..Default::default()
    };
    let event_loop = srv_cfg.event_loop;
    let handlers = srv_cfg.handlers;
    let server = Arc::new(ama::server::Server::bind_with(
        &format!("127.0.0.1:{port}"),
        coord.handle(),
        srv_cfg,
    )?);
    println!(
        "ama serving on {} ({handlers} handlers, backend {backend}, ingest {}; protocols: AMA/1 JSON-lines + legacy bare-line)",
        server.local_addr()?,
        if event_loop { "event-loop" } else { "blocking pool" }
    );
    let metrics = {
        let svc = coord.metrics_arc();
        let srv = server.clone();
        let render: Arc<dyn Fn() -> String + Send + Sync> = Arc::new(move || {
            let mut out = ama::metrics::PromText::new();
            svc.render_prometheus(&mut out);
            render_conn_stats(&mut out, &srv.stats);
            #[cfg(unix)]
            render_loop_stats(&mut out, &srv.loop_stats());
            out.finish()
        });
        start_metrics_endpoint(args, render)?
    };
    server.serve_forever()?;
    if let Some(ms) = metrics {
        ms.stop();
    }
    coord.shutdown();
    Ok(())
}

/// `ama loadtest`: stand up the full coordinator + TCP server in-process,
/// drive it with a client fleet in per-word and/or pipelined mode, and
/// report p50/p90/p99 + words/sec (optionally as a BENCH_PR*.json row).
fn cmd_loadtest(args: &Args) -> Result<()> {
    let conns = args.flag_usize("--conns", 32).map_err(|e| anyhow!(e))?;
    let secs = args.flag_u64("--secs", 5).map_err(|e| anyhow!(e))?;
    let depth = args.flag_usize("--depth", 64).map_err(|e| anyhow!(e))?;
    let mode = args.flag_or("--mode", "both");
    let proto = args.flag_or("--proto", "line");
    anyhow::ensure!(
        matches!(proto, "line" | "ama1"),
        "unknown proto {proto:?} (line|ama1)"
    );
    // C10K profile (PR 9): park `idle_frac` of the connections as
    // keepalive, burst the rest, and demand a flat p99 vs a 32-conn
    // baseline. `0` (default) keeps the classic all-active modes.
    let idle_frac = flag_f64(args, "--idle-frac", 0.0)?;
    anyhow::ensure!(
        (0.0..1.0).contains(&idle_frac),
        "--idle-frac must be in [0, 1), got {idle_frac}"
    );
    let idle_mode = idle_frac > 0.0;
    anyhow::ensure!(
        !idle_mode || proto == "line",
        "--idle-frac drives the legacy line protocol; drop --proto ama1"
    );
    let event_loop = parse_event_loop(args)?;
    let loops = args.flag_usize("--loops", 0).map_err(|e| anyhow!(e))?;
    // AMA/1 load defaults to the registry backend so the fleet can
    // exercise per-request algorithms; the legacy-line default keeps the
    // BENCH_PR2 comparison backend.
    let backend = args
        .flag("--backend")
        .unwrap_or(if proto == "ama1" { "registry" } else { "software-par" });
    // AMA/1 fleet option sets: one --algo pins every connection; without
    // it the fleet cycles all four algorithms across connections.
    let opts_cycle: Vec<AnalyzeOptions> = match args.flag("--algo") {
        Some(name) => vec![AnalyzeOptions::with_algorithm(
            Algorithm::from_name(name).ok_or_else(|| anyhow!("unknown --algo {name:?}"))?,
        )],
        None if backend == "registry" => {
            Algorithm::ALL.iter().map(|&a| AnalyzeOptions::with_algorithm(a)).collect()
        }
        None => vec![AnalyzeOptions::default()],
    };
    let workers = args.flag_usize("--workers", 1).map_err(|e| anyhow!(e))?;
    let pr = args.flag_u64("--pr", 2).map_err(|e| anyhow!(e))?;
    let roots = load_roots(args)?;
    let n_words = args.flag_usize("--words", 4096).map_err(|e| anyhow!(e))?;
    let corpus = corpus::generate(&roots, &CorpusConfig::small(n_words, 29));
    let words: Vec<String> = corpus.tokens.iter().map(|t| t.word.to_string_ar()).collect();

    // Each plan row: (name, connection count, pipeline depth).
    let plan: Vec<(String, usize, usize)> = if idle_mode {
        // Baseline first so the flat-p99 ratio reads rows[1]/rows[0].
        vec![
            ("mostly-idle-32".to_string(), 32, depth),
            (format!("mostly-idle-{conns}"), conns, depth),
        ]
    } else {
        match mode {
            "per-word" => vec![("per-word".to_string(), conns, 1)],
            "pipelined" => vec![("pipelined".to_string(), conns, depth)],
            "both" => vec![
                ("per-word".to_string(), conns, 1),
                ("pipelined".to_string(), conns, depth),
            ],
            other => bail!("unknown mode {other:?} (per-word|pipelined|both)"),
        }
    };

    let mut rows: Vec<(String, ama::bench::LoadOutcome, ama::metrics::MetricsSnapshot)> =
        Vec::new();
    for (mode_name, row_conns, depth) in plan {
        let mode_name = mode_name.as_str();
        // Fresh stack per mode so metrics and batching state don't bleed.
        let cfg = CoordinatorConfig {
            workers,
            max_batch: args.flag_usize("--batch", 256).map_err(|e| anyhow!(e))?,
            ..Default::default()
        };
        let coord = start_coordinator(args, backend, roots.clone(), true, cfg)?;
        let srv_cfg = ama::server::ServerConfig {
            // Blocking fallback: one handler per connection so the pool
            // never gates the fleet. The event-loop path (default) sizes
            // itself and ignores `handlers`.
            handlers: row_conns,
            event_loop,
            loops,
            ..Default::default()
        };
        let server =
            Arc::new(ama::server::Server::bind_with("127.0.0.1:0", coord.handle(), srv_cfg)?);
        let addr = server.local_addr()?;
        let srv = server.clone();
        let serve_thread = std::thread::spawn(move || srv.serve_forever());

        println!(
            "loadtest[{mode_name}/{proto}]: {row_conns} conns × {secs}s against {addr} ({backend})…"
        );
        let outcome = if idle_mode {
            ama::bench::run_mostly_idle_load(
                addr,
                row_conns,
                idle_frac,
                Duration::from_secs(secs),
                depth,
                &words,
            )
        } else {
            match proto {
                "ama1" => ama::bench::run_ama1_load(
                    addr,
                    conns,
                    Duration::from_secs(secs),
                    depth,
                    &words,
                    &opts_cycle,
                ),
                _ => {
                    ama::bench::run_tcp_load(addr, conns, Duration::from_secs(secs), depth, &words)
                }
            }
        };
        let snap = coord.metrics().snapshot();
        println!("  client: {outcome}");
        println!("  server: {snap}");

        server.stop();
        serve_thread.join().expect("serve thread")?;
        coord.shutdown();
        anyhow::ensure!(outcome.reorders == 0, "protocol reordered {} replies", outcome.reorders);
        // A degraded fleet must not produce the headline speedup or the
        // BENCH_PR*.json row as if the run were healthy.
        anyhow::ensure!(
            outcome.errors == 0 && snap.errors == 0,
            "loadtest not clean: {} client I/O errors, {} server errors",
            outcome.errors,
            snap.errors
        );
        rows.push((mode_name.to_string(), outcome, snap));
    }

    let mut p99_flat_ratio: Option<f64> = None;
    if idle_mode && rows.len() == 2 {
        let base = rows[0].1.rtt_p99_us;
        let big = rows[1].1.rtt_p99_us;
        if base > 0 && big > 0 {
            let ratio = big as f64 / base as f64;
            p99_flat_ratio = Some(ratio);
            println!(
                "\np99 flat check: {} conns p99 {}us vs 32-conn baseline {}us ({ratio:.2}x)",
                rows[1].1.conns, big, base
            );
            // "Flat" with histogram-bucket tolerance: the RTT histogram
            // buckets are powers of two, so allow two bucket steps.
            anyhow::ensure!(
                ratio <= 4.0,
                "p99 not flat under mostly-idle C10K load: {ratio:.2}x vs 32-conn baseline"
            );
        }
    } else if rows.len() == 2 {
        let per_word = rows[0].1.wps();
        let pipelined = rows[1].1.wps();
        if per_word > 0.0 {
            println!(
                "\npipelined vs per-word: {:.2}x words/sec ({:.0} vs {:.0})",
                pipelined / per_word,
                pipelined,
                per_word
            );
        }
    }

    if let Some(out_path) = args.flag("--out") {
        let mut json = String::new();
        json.push_str("{\n");
        json.push_str("  \"schema\": \"ama-loadtest-v1\",\n");
        json.push_str(&format!("  \"pr\": {pr},\n"));
        json.push_str(&format!("  \"proto\": \"{proto}\",\n"));
        json.push_str(&format!("  \"backend\": \"{backend}\",\n"));
        json.push_str(&format!("  \"conns\": {conns},\n"));
        json.push_str(&format!("  \"secs\": {secs},\n"));
        json.push_str(&format!("  \"coordinator_workers\": {workers},\n"));
        if idle_mode {
            json.push_str(&format!("  \"idle_frac\": {idle_frac},\n"));
        }
        if let Some(ratio) = p99_flat_ratio {
            json.push_str(&format!("  \"p99_flat_ratio_vs_32\": {ratio:.3},\n"));
        }
        if !idle_mode && rows.len() == 2 && rows[0].1.wps() > 0.0 {
            json.push_str(&format!(
                "  \"speedup_pipelined_vs_per_word\": {:.3},\n",
                rows[1].1.wps() / rows[0].1.wps()
            ));
        }
        json.push_str("  \"results\": [\n");
        for (i, (name, o, snap)) in rows.iter().enumerate() {
            json.push_str(&format!(
                "    {{\"name\": \"{name}\", \"conns\": {}, \"depth\": {}, \"words\": {}, \"wps\": {:.1}, \
                 \"rtt_p50_us\": {}, \"rtt_p90_us\": {}, \"rtt_p99_us\": {}, \
                 \"server_p50_us\": {}, \"server_p90_us\": {}, \"server_p99_us\": {}, \
                 \"mean_batch\": {:.2}, \"queue_full\": {}, \"slab_waits\": {}, \
                 \"cache_hits\": {}, \"cache_misses\": {}, \"cache_hit_rate\": {:.4}, \
                 \"errors\": {}}}{}\n",
                o.conns,
                o.depth,
                o.words,
                o.wps(),
                o.rtt_p50_us,
                o.rtt_p90_us,
                o.rtt_p99_us,
                snap.p50_us,
                snap.p90_us,
                snap.p99_us,
                snap.mean_batch_size,
                snap.queue_full_events,
                snap.slab_waits,
                snap.cache_hits,
                snap.cache_misses,
                snap.cache_hit_rate(),
                o.errors + snap.errors,
                if i + 1 < rows.len() { "," } else { "" }
            ));
        }
        json.push_str("  ]\n}\n");
        std::fs::write(out_path, &json).with_context(|| format!("writing {out_path}"))?;
        println!("wrote {out_path}");
    }
    Ok(())
}

/// `ama emit-hlo`: lower the stemmer dataflow to HLO-text artifacts from
/// rust — the offline replacement for the JAX leg of `make artifacts`
/// (aot.py is preferred when `jax` is importable; the two emit the same
/// graph semantics and the same file names).
fn cmd_emit_hlo(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.flag_or("--out", "artifacts"));
    let batches: Vec<usize> = match args.flag("--batches") {
        None => ama::runtime::BATCHES.to_vec(),
        Some(spec) => spec
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--batches: invalid batch size {s:?}"))
            })
            .collect::<Result<_>>()?,
    };
    anyhow::ensure!(!batches.is_empty(), "--batches: no batch sizes given");
    let paths = ama::runtime::emit::write_artifacts(&out, &batches)?;
    for p in &paths {
        let bytes = std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
        println!("wrote {} ({bytes} bytes)", p.display());
    }
    Ok(())
}

/// `ama bench json`: run the software / hw-sim benchmark suite and write a
/// machine-readable JSON report (the `BENCH_PR*.json` perf trajectory).
fn cmd_bench(args: &Args) -> Result<()> {
    let mode = args.positionals.get(1).map(String::as_str).unwrap_or("json");
    if mode != "json" {
        bail!("usage: ama bench json [--out FILE] [--words N]");
    }
    let out_path = args.flag_or("--out", "BENCH_PR1.json").to_string();
    let pr = args.flag_u64("--pr", 1).map_err(|e| anyhow!(e))?;
    let roots = load_roots(args)?;
    let n_words = args.flag_usize("--words", 0).map_err(|e| anyhow!(e))?;
    let corpus = if n_words == 0 {
        corpus::generate(&roots, &CorpusConfig::quran())
    } else {
        corpus::generate(&roots, &CorpusConfig::small(n_words, 11))
    };
    let words: Vec<ArabicWord> = corpus.tokens.iter().map(|t| t.word).collect();
    let n = words.len() as u64;
    let cfg = ama::bench::config_from_env();
    let threads = std::thread::available_parallelism().map_or(1, |p| p.get());

    let mut rows: Vec<ama::bench::BenchResult> = Vec::new();
    let stemmer = Stemmer::with_defaults(roots.clone());

    let r = ama::bench::bench_words("software/stem_reference", &cfg, n, || {
        let mut acc = 0usize;
        for w in &words {
            acc += stemmer.stem_reference(w).kind as usize;
        }
        std::hint::black_box(acc);
    });
    println!("{r}");
    let reference_wps = r.wps().unwrap_or(0.0);
    rows.push(r);

    let r = ama::bench::bench_words("software/stem", &cfg, n, || {
        let mut acc = 0usize;
        for w in &words {
            acc += stemmer.stem(w).kind as usize;
        }
        std::hint::black_box(acc);
    });
    println!("{r}");
    let fused_wps = r.wps().unwrap_or(0.0);
    rows.push(r);

    for batch in [64usize, 256, 1024, 8192] {
        let r = ama::bench::bench_words(&format!("software/stem_batch/b{batch}"), &cfg, n, || {
            let mut acc = 0usize;
            for chunk in words.chunks(batch) {
                for res in stemmer.stem_batch(chunk) {
                    acc += res.kind as usize;
                }
            }
            std::hint::black_box(acc);
        });
        println!("{r}");
        rows.push(r);
    }

    let r = ama::bench::bench_words(
        &format!("software/stem_batch_parallel/t{threads}"),
        &cfg,
        n,
        || {
            let res = stemmer.stem_batch_parallel(&words, threads);
            std::hint::black_box(res.len());
        },
    );
    println!("{r}");
    rows.push(r);

    // PR 4 rows: the packed-register kernel vs the array kernel, and the
    // registry dispatch with the memoizing cache warm vs off.
    let packed: Vec<ama::chars::PackedWord> =
        words.iter().map(ama::chars::PackedWord::pack).collect();
    let r = ama::bench::bench_words("software/stem_packed", &cfg, n, || {
        let mut acc = 0usize;
        for &p in &packed {
            acc += stemmer.stem_packed(p).kind as usize;
        }
        std::hint::black_box(acc);
    });
    println!("{r}");
    let packed_wps = r.wps().unwrap_or(0.0);
    rows.push(r);

    // Pin this row to the scalar kernel: it is the baseline the SIMD row
    // is measured against, so it must not dispatch to SIMD itself.
    let r = ama::bench::bench_words("software/stem_batch_packed", &cfg, n, || {
        let res = stemmer.stem_batch_packed_scalar(&packed);
        std::hint::black_box(res.len());
    });
    println!("{r}");
    let batch_packed_wps = r.wps().unwrap_or(0.0);
    rows.push(r);

    // PR 6 row: the lane-parallel SIMD kernel (AVX2/NEON when available,
    // portable min-fold otherwise — the row name stays stable either way
    // so trajectories compare like against like; `simd_path` in the JSON
    // header records what actually ran).
    let simd_path = ama::simd::active().unwrap_or_else(ama::simd::best_available);
    let r = ama::bench::bench_words("software/stem_batch_simd", &cfg, n, || {
        let res = ama::simd::stem_batch_simd_with(&stemmer, &packed, simd_path);
        std::hint::black_box(res.len());
    });
    println!("{r}");
    let simd_wps = r.wps().unwrap_or(0.0);
    rows.push(r);

    let cache_metrics = Arc::new(ama::metrics::ServiceMetrics::new());
    let mut cached_backend = RegistryBackend::with_cache(
        roots.clone(),
        StemmerConfig::default(),
        Some(ama::cache::StemCache::new(1 << 16)),
        Some(cache_metrics.clone()),
    );
    let opts = ama::analysis::EngineOpts::default();
    // One warm pass seeds the cache; the measured iterations then run
    // the hit path (the corpus re-uses surface forms, as real text does).
    std::hint::black_box(
        cached_backend.analyze_batch_packed(&packed, opts).expect("warm pass").len(),
    );
    let r = ama::bench::bench_words("serve/registry_cache_warm", &cfg, n, || {
        let res = cached_backend.analyze_batch_packed(&packed, opts).expect("cache bench");
        std::hint::black_box(res.len());
    });
    println!("{r}");
    let cache_warm_wps = r.wps().unwrap_or(0.0);
    rows.push(r);

    let mut uncached_backend =
        RegistryBackend::with_config(roots.clone(), StemmerConfig::default());
    let r = ama::bench::bench_words("serve/registry_cache_off", &cfg, n, || {
        let res = uncached_backend.analyze_batch_packed(&packed, opts).expect("cache bench");
        std::hint::black_box(res.len());
    });
    println!("{r}");
    let cache_off_wps = r.wps().unwrap_or(0.0);
    rows.push(r);
    let cache_snap = cache_metrics.snapshot();

    // PR 5 rows: the interpreter-backed runtime engine per artifact batch
    // size. Artifacts are emitted to a per-process scratch dir by the rust
    // lowerer so the rows never depend on `make artifacts` having run (and
    // concurrent/multi-user bench runs cannot collide in /tmp).
    let art_dir = std::env::temp_dir().join(format!("ama_bench_artifacts_{}", std::process::id()));
    ama::runtime::emit::write_artifacts(&art_dir, ama::runtime::BATCHES)
        .context("emitting bench artifacts")?;
    let engine = Engine::load(&art_dir, &roots).context("loading runtime engine for bench")?;
    for b in engine.batch_sizes() {
        let chunk = &words[..b.min(words.len())];
        let r = ama::bench::bench_words(
            &format!("runtime/stem_chunk_b{b}"),
            &cfg,
            chunk.len() as u64,
            || {
                let res = engine.stem_chunk(chunk).expect("runtime exec");
                std::hint::black_box(res.len());
            },
        );
        println!("{r}");
        rows.push(r);
    }

    use ama::hw::Processor as _;
    let dp = DatapathConfig { infix_units: true };
    let r = ama::bench::bench_words("hw-sim/pipelined (wall-clock)", &cfg, n, || {
        let mut p = PipelinedProcessor::new(roots.clone(), dp);
        let (res, _) = p.run(&words);
        std::hint::black_box(res.len());
    });
    println!("{r}");
    rows.push(r);

    // PR 8 rows: staged-pipeline index build plus root search over the
    // built index (in-process registry path — same packed/SIMD kernels
    // the serving rows measure).
    let reg = Arc::new(AnalyzerRegistry::new(roots.clone()));
    let pipe_cfg = ama::index::pipeline::PipelineConfig {
        opts: AnalyzeOptions::with_algorithm(Algorithm::Voting),
        ..Default::default()
    };
    let mut built: Option<ama::index::CorpusIndex> = None;
    let r = ama::bench::bench_words("index/pipeline_build", &cfg, n, || {
        let stages = ama::index::pipeline::build_stages(
            ama::index::pipeline::AnalyzeVia::Registry(reg.clone()),
            &pipe_cfg,
            None,
        );
        let run =
            ama::index::pipeline::run(stages, ama::index::corpus_units(&corpus, 64), &pipe_cfg);
        built = Some(ama::index::index_from_run(&run));
    });
    println!("{r}");
    let index_build_wps = r.wps().unwrap_or(0.0);
    rows.push(r);
    let built = built.expect("bench ran at least once");

    // One-word root queries over the first corpus words (the retrieval
    // common case); the row's "wps" is searches/sec.
    let query_analyses =
        reg.analyze_batch_packed(&packed[..64.min(packed.len())], &pipe_cfg.opts);
    let (query_keys, _) = ama::index::keys_from_analyses(&query_analyses);
    let r = ama::bench::bench_words("index/search", &cfg, query_keys.len() as u64, || {
        let mut acc = 0usize;
        for &k in &query_keys {
            acc += built.search(&[k], 10).len();
        }
        std::hint::black_box(acc);
    });
    println!("{r}");
    rows.push(r);

    // The PR 8 bugfix: the accuracy harness results are wired into the
    // bench JSON (previously `AccuracyReport` never reached `bench json`
    // — the perf trajectory had no accuracy-vs-paper record at all).
    let (acc_base, acc_rr) = ama::index::accuracy_harness(
        ama::index::pipeline::AnalyzeVia::Registry(reg.clone()),
        &roots,
        &corpus,
        &pipe_cfg,
        64,
    );

    let speedup = if reference_wps > 0.0 { fused_wps / reference_wps } else { 0.0 };
    // Same datapath config as the measured rows (fmax/cycle model is
    // config-independent, but keep the report internally consistent).
    let np = NonPipelinedProcessor::new(roots.clone(), dp);
    let pp = PipelinedProcessor::new(roots.clone(), dp);

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"ama-bench-v1\",\n");
    json.push_str(&format!("  \"pr\": {pr},\n"));
    json.push_str(&format!(
        "  \"corpus\": {{\"name\": \"{}\", \"words\": {}}},\n",
        corpus.name,
        words.len()
    ));
    json.push_str(&format!("  \"dictionary_roots\": {},\n", roots.total()));
    json.push_str(&format!("  \"threads\": {threads},\n"));
    json.push_str(&format!(
        "  \"fast_mode\": {},\n",
        std::env::var_os("AMA_BENCH_FAST").is_some()
    ));
    json.push_str(&format!(
        "  \"speedup_stem_vs_reference\": {speedup:.3},\n"
    ));
    let speedup_packed = if fused_wps > 0.0 { packed_wps / fused_wps } else { 0.0 };
    let speedup_cache = if cache_off_wps > 0.0 { cache_warm_wps / cache_off_wps } else { 0.0 };
    let speedup_simd =
        if batch_packed_wps > 0.0 { simd_wps / batch_packed_wps } else { 0.0 };
    // How much of the paper's pipelined-processor model throughput the
    // best software kernel reaches — the gap this PR exists to close.
    let pp_wps = pp.throughput_wps(n);
    let pct_of_hw = if pp_wps > 0.0 { 100.0 * simd_wps / pp_wps } else { 0.0 };
    json.push_str(&format!(
        "  \"speedup_packed_vs_array\": {speedup_packed:.3},\n"
    ));
    json.push_str(&format!(
        "  \"speedup_simd_vs_packed\": {speedup_simd:.3},\n"
    ));
    json.push_str(&format!("  \"pct_of_hw_model_wps\": {pct_of_hw:.3},\n"));
    json.push_str(&format!("  \"simd_path\": \"{}\",\n", simd_path.name()));
    json.push_str(&format!(
        "  \"speedup_cache_warm_vs_off\": {speedup_cache:.3},\n"
    ));
    json.push_str(&format!(
        "  \"cache_hit_rate\": {:.4},\n",
        cache_snap.cache_hit_rate()
    ));
    json.push_str(&format!(
        "  \"hw_model_wps\": {{\"non_pipelined\": {:.1}, \"pipelined\": {:.1}}},\n",
        np.throughput_wps(n),
        pp.throughput_wps(n)
    ));
    json.push_str(&format!(
        "  \"index_build_wps\": {index_build_wps:.1},\n"
    ));
    json.push_str(&format!(
        "  \"accuracy\": {{\"corpus\": \"{}\", \"roots_present\": {}, \
         \"baseline\": {{\"stemmer\": \"{}\", \"roots_recovered\": {}, \
         \"root_accuracy\": {:.4}, \"word_accuracy\": {:.4}}}, \
         \"rerank\": {{\"stemmer\": \"{}\", \"roots_recovered\": {}, \
         \"root_accuracy\": {:.4}, \"word_accuracy\": {:.4}}}, \
         \"reference\": {{\"quran_infix\": {:.3}, \"ankabut\": {:.3}}}}},\n",
        corpus.name,
        acc_base.roots_present,
        acc_base.stemmer,
        acc_base.roots_recovered,
        acc_base.root_accuracy(),
        acc_base.word_accuracy(),
        acc_rr.stemmer,
        acc_rr.roots_recovered,
        acc_rr.root_accuracy(),
        acc_rr.word_accuracy(),
        ama::index::PAPER_QURAN_ROOT_ACCURACY,
        ama::index::PAPER_ANKABUT_ROOT_ACCURACY,
    ));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let wps = r.wps().unwrap_or(0.0);
        let ns_per_word = if n > 0 { r.mean.as_nanos() as f64 / n as f64 } else { 0.0 };
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"wps\": {:.1}, \"ns_per_word\": {:.2}, \"iters\": {}}}{}\n",
            r.name,
            wps,
            ns_per_word,
            r.iters,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write(&out_path, &json).with_context(|| format!("writing {out_path}"))?;
    println!("\nspeedup stem vs stem_reference: {speedup:.2}x");
    println!("speedup stem_packed vs stem:    {speedup_packed:.2}x");
    println!(
        "speedup simd vs packed batch:   {speedup_simd:.2}x (path {})",
        simd_path.name()
    );
    println!("pct of hw pipelined model:      {pct_of_hw:.2}%");
    println!(
        "speedup cache warm vs off:      {speedup_cache:.2}x (hit rate {:.1}%)",
        100.0 * cache_snap.cache_hit_rate()
    );
    println!("index pipeline build:           {index_build_wps:.0} words/sec");
    println!(
        "pipeline accuracy (roots):      {:.1}% base, {:.1}% +rerank (paper 87.7%/90.7%)",
        100.0 * acc_base.root_accuracy(),
        100.0 * acc_rr.root_accuracy()
    );
    println!("wrote {out_path}");
    Ok(())
}

fn flag_f64(args: &Args, name: &str, default: f64) -> Result<f64> {
    match args.flag(name) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("{name}: invalid number {v:?}")),
    }
}

/// `--event-loop on|off` (PR 9; default on — unsupported platforms fall
/// back to the blocking pool by themselves).
fn parse_event_loop(args: &Args) -> Result<bool> {
    match args.flag_or("--event-loop", "on") {
        "on" => Ok(true),
        "off" => Ok(false),
        other => bail!("--event-loop: {other:?} (on|off)"),
    }
}

/// Connection-accounting series for the `/metrics` endpoint.
fn render_conn_stats(out: &mut ama::metrics::PromText, stats: &ama::server::ConnStats) {
    out.counter(
        "ama_connections_accepted_total",
        "Connections accepted by the serve front",
        stats.accepted(),
    );
    out.gauge(
        "ama_connections_active",
        "Connections currently owned by a handler (blocking path)",
        stats.active(),
    );
    out.counter(
        "ama_connections_completed_total",
        "Connections fully served and closed",
        stats.completed(),
    );
}

/// Per-event-loop series for the `/metrics` endpoint (empty slice —
/// blocking path — renders nothing).
#[cfg(unix)]
fn render_loop_stats(out: &mut ama::metrics::PromText, loops: &[Arc<ama::net::LoopStats>]) {
    use ama::chk::sync::atomic::Ordering;
    if loops.is_empty() {
        return;
    }
    fn rows(
        loops: &[Arc<ama::net::LoopStats>],
        f: impl Fn(&ama::net::LoopStats) -> u64,
    ) -> Vec<(String, u64)> {
        loops.iter().enumerate().map(|(i, s)| (format!("loop=\"{i}\""), f(s))).collect()
    }
    out.labeled_counter(
        "ama_loop_connections_accepted_total",
        "Connections handed to each event loop",
        &rows(loops, |s| s.accepted.load(Ordering::Relaxed)), // ord: Relaxed — stats
    );
    out.labeled_gauge(
        "ama_loop_connections_open",
        "Connections currently registered per event loop",
        &rows(loops, |s| s.open.load(Ordering::Relaxed)), // ord: Relaxed — stats
    );
    out.labeled_counter(
        "ama_loop_readiness_events_total",
        "Readiness events delivered per event loop",
        &rows(loops, |s| s.readiness_events.load(Ordering::Relaxed)), // ord: Relaxed — stats
    );
    out.labeled_counter(
        "ama_loop_wakeups_total",
        "Waker drains per event loop (stop/inject/completion pokes)",
        &rows(loops, |s| s.wakeups.load(Ordering::Relaxed)), // ord: Relaxed — stats
    );
    out.labeled_counter(
        "ama_loop_reads_total",
        "read(2) calls per event loop",
        &rows(loops, |s| s.reads.load(Ordering::Relaxed)), // ord: Relaxed — stats
    );
    out.labeled_counter(
        "ama_loop_writes_total",
        "write(2) calls per event loop",
        &rows(loops, |s| s.writes.load(Ordering::Relaxed)), // ord: Relaxed — stats
    );
    out.labeled_counter(
        "ama_loop_read_pauses_total",
        "Backpressure transitions: reads paused on slow readers, per loop",
        &rows(loops, |s| s.pauses.load(Ordering::Relaxed)), // ord: Relaxed — stats
    );
}

/// Start the Prometheus side-port endpoint if `--metrics-port` was given.
fn start_metrics_endpoint(
    args: &Args,
    render: Arc<dyn Fn() -> String + Send + Sync>,
) -> Result<Option<ama::metrics::MetricsServer>> {
    let Some(p) = args.flag("--metrics-port") else { return Ok(None) };
    let port: u16 = p.parse().map_err(|_| anyhow!("--metrics-port: invalid port {p:?}"))?;
    let ms = ama::metrics::MetricsServer::start(&format!("127.0.0.1:{port}"), render)?;
    println!("metrics endpoint on http://{}/metrics (Prometheus text)", ms.local_addr());
    Ok(Some(ms))
}

/// Gateway policy from the shared flag set (used by both `ama gateway`
/// and `ama gateway-loadtest`).
fn gateway_config(args: &Args) -> Result<ama::gateway::GatewayConfig> {
    use ama::gateway::breaker::BreakerConfig;
    use ama::gateway::pool::PoolConfig;
    Ok(ama::gateway::GatewayConfig {
        handlers: args.flag_usize("--handlers", 8).map_err(|e| anyhow!(e))?,
        pool: PoolConfig {
            breaker: BreakerConfig {
                failure_threshold: args
                    .flag_u64("--failure-threshold", 3)
                    .map_err(|e| anyhow!(e))? as u32,
                cooldown: Duration::from_millis(
                    args.flag_u64("--cooldown-ms", 500).map_err(|e| anyhow!(e))?,
                ),
            },
            ..PoolConfig::default()
        },
        request_deadline: Duration::from_millis(
            args.flag_u64("--deadline-ms", 2000).map_err(|e| anyhow!(e))?,
        ),
        probe_interval: Duration::from_millis(
            args.flag_u64("--probe-ms", 100).map_err(|e| anyhow!(e))?,
        ),
        rate_per_sec: flag_f64(args, "--rate", 0.0)?,
        burst: flag_f64(args, "--burst", 0.0)?,
        max_in_flight: args.flag_usize("--max-in-flight", 0).map_err(|e| anyhow!(e))?,
        event_loop: parse_event_loop(args)?,
        loops: args.flag_usize("--loops", 0).map_err(|e| anyhow!(e))?,
        ..ama::gateway::GatewayConfig::default()
    })
}

/// `ama gateway`: the PR 7 fault-tolerant sharding tier. Fronts either an
/// explicit `--endpoints` list of running `ama serve` replicas, or
/// `--replicas N` in-process ones (a single-command demo topology).
fn cmd_gateway(args: &Args) -> Result<()> {
    use ama::gateway::fleet::{Fleet, FleetConfig};
    use ama::gateway::{Gateway, GatewayServer};

    let cfg = gateway_config(args)?;
    let (endpoints, _fleet): (Vec<std::net::SocketAddr>, Option<Fleet>) =
        match args.flag("--endpoints") {
            Some(spec) => {
                use std::net::ToSocketAddrs as _;
                let mut addrs = Vec::new();
                for item in spec.split(',') {
                    let item = item.trim();
                    addrs.push(
                        item.to_socket_addrs()
                            .with_context(|| format!("resolving endpoint {item}"))?
                            .next()
                            .ok_or_else(|| anyhow!("{item} resolved to no address"))?,
                    );
                }
                anyhow::ensure!(!addrs.is_empty(), "--endpoints: empty list");
                (addrs, None)
            }
            None => {
                let n = args.flag_usize("--replicas", 2).map_err(|e| anyhow!(e))?.max(1);
                let fleet = Fleet::start(n, FleetConfig::with_roots(load_roots(args)?));
                println!("started {n} in-process replicas: {:?}", fleet.addrs());
                let addrs = fleet.addrs().to_vec();
                (addrs, Some(fleet))
            }
        };

    let gw = Arc::new(Gateway::new(&endpoints, cfg));
    let port = args.flag_usize("--port", 7610).map_err(|e| anyhow!(e))?;
    let server = Arc::new(GatewayServer::bind(&format!("127.0.0.1:{port}"), gw.clone())?);
    println!(
        "ama gateway on {} -> {} replicas ({} handlers, ingest {}; AMA/1 only; breaker \
         threshold={} cooldown={}ms; probe every {}ms)",
        server.local_addr()?,
        endpoints.len(),
        cfg.handlers,
        if cfg.event_loop { "event-loop" } else { "blocking pool" },
        cfg.pool.breaker.failure_threshold,
        cfg.pool.breaker.cooldown.as_millis(),
        cfg.probe_interval.as_millis(),
    );
    let metrics = {
        let gwm = gw.metrics().clone();
        let srv = server.clone();
        let render: Arc<dyn Fn() -> String + Send + Sync> = Arc::new(move || {
            let mut out = ama::metrics::PromText::new();
            gwm.render_prometheus(&mut out);
            #[cfg(unix)]
            render_loop_stats(&mut out, &srv.loop_stats());
            #[cfg(not(unix))]
            let _ = &srv;
            out.finish()
        });
        start_metrics_endpoint(args, render)?
    };
    server.serve_forever()?;
    if let Some(ms) = metrics {
        ms.stop();
    }
    Ok(())
}

/// `ama gateway-loadtest`: chaos/scaling harness behind one command.
///
/// * overhead — direct-vs-gateway AMA/1 load against the same 1-replica
///   fleet (the <20% p50 acceptance figure);
/// * scaling — gateway throughput at 1..N replicas;
/// * `--chaos` — kill replica 0 mid-run and restart it, requiring zero
///   errors/reorders and a visible breaker trip (the verify.sh smoke
///   greps the `breaker tripped` / `zero-loss OK` lines).
fn cmd_gateway_loadtest(args: &Args) -> Result<()> {
    use ama::gateway::breaker::BreakerConfig;
    use ama::gateway::fleet::{Fleet, FleetConfig};
    use ama::gateway::{Gateway, GatewayServer};

    let replicas = args.flag_usize("--replicas", 3).map_err(|e| anyhow!(e))?.max(1);
    let conns = args.flag_usize("--conns", 16).map_err(|e| anyhow!(e))?.max(1);
    let secs = args.flag_u64("--secs", 4).map_err(|e| anyhow!(e))?.max(1);
    let depth = args.flag_usize("--depth", 8).map_err(|e| anyhow!(e))?.max(1);
    let duration = Duration::from_secs(secs);
    let roots = load_roots(args)?;
    let n_words = args.flag_usize("--words", 2048).map_err(|e| anyhow!(e))?;
    let corpus = corpus::generate(&roots, &CorpusConfig::small(n_words, 29));
    let words: Vec<String> = corpus.tokens.iter().map(|t| t.word.to_string_ar()).collect();
    // Mixed load: the fleet's registry backend serves all four engines.
    let opts_cycle: Vec<AnalyzeOptions> =
        Algorithm::ALL.iter().map(|&a| AnalyzeOptions::with_algorithm(a)).collect();
    // Snappy fault policy so a short run can observe a full breaker cycle.
    let mut cfg = gateway_config(args)?;
    cfg.handlers = conns;
    if args.flag("--failure-threshold").is_none() {
        cfg.pool.breaker = BreakerConfig {
            failure_threshold: 2,
            cooldown: Duration::from_millis(args.flag_u64("--cooldown-ms", 250).unwrap_or(250)),
        };
    }
    if args.flag("--probe-ms").is_none() {
        cfg.probe_interval = Duration::from_millis(50);
    }

    let fleet_cfg = FleetConfig::with_roots(roots.clone());
    let gw_stack = |n: usize| -> Result<(Fleet, Arc<Gateway>, Arc<GatewayServer>, std::net::SocketAddr)> {
        let fleet = Fleet::start(n, fleet_cfg.clone());
        let gw = Arc::new(Gateway::new(fleet.addrs(), cfg));
        let server = Arc::new(GatewayServer::bind("127.0.0.1:0", gw.clone())?);
        let addr = server.local_addr()?;
        Ok((fleet, gw, server, addr))
    };
    let stop_stack = |server: Arc<GatewayServer>,
                      t: std::thread::JoinHandle<Result<()>>,
                      fleet: Fleet|
     -> Result<()> {
        server.stop();
        t.join().expect("gateway serve thread")?;
        fleet.shutdown();
        Ok(())
    };

    // --- overhead: the same 1-replica fleet, direct vs through the gateway
    println!("gateway-loadtest: overhead at 1 replica ({conns} conns × {secs}s, depth {depth})…");
    let fleet = Fleet::start(1, fleet_cfg.clone());
    let direct =
        ama::bench::run_ama1_load(fleet.addrs()[0], conns, duration, depth, &words, &opts_cycle);
    println!("  direct : {direct}");
    let gw = Arc::new(Gateway::new(fleet.addrs(), cfg));
    let server = Arc::new(GatewayServer::bind("127.0.0.1:0", gw.clone())?);
    let addr = server.local_addr()?;
    let srv = server.clone();
    let t = std::thread::spawn(move || srv.serve_forever());
    let gated = ama::bench::run_ama1_load(addr, conns, duration, depth, &words, &opts_cycle);
    println!("  gateway: {gated}");
    anyhow::ensure!(direct.errors + gated.errors == 0, "overhead phase not clean");
    anyhow::ensure!(direct.reorders + gated.reorders == 0, "overhead phase reordered");
    let overhead_p50 = if direct.rtt_p50_us > 0 {
        gated.rtt_p50_us as f64 / direct.rtt_p50_us as f64 - 1.0
    } else {
        0.0
    };
    println!("  p50 overhead through the gateway: {:+.1}%", 100.0 * overhead_p50);
    server.stop();
    t.join().expect("gateway serve thread")?;
    fleet.shutdown();

    // --- scaling: gateway throughput at 1..replicas
    let mut scaling: Vec<(usize, ama::bench::LoadOutcome)> = Vec::new();
    let mut n = 1usize;
    while n <= replicas {
        println!("gateway-loadtest: scaling at {n} replica(s)…");
        let (fleet, _gw, server, addr) = gw_stack(n)?;
        let srv = server.clone();
        let t = std::thread::spawn(move || srv.serve_forever());
        let o = ama::bench::run_ama1_load(addr, conns, duration, depth, &words, &opts_cycle);
        println!("  {o}");
        anyhow::ensure!(o.errors == 0 && o.reorders == 0, "scaling phase not clean at {n}");
        stop_stack(server, t, fleet)?;
        scaling.push((n, o));
        n = if n * 2 <= replicas || n == replicas { n * 2 } else { replicas };
    }

    // --- chaos: kill replica 0 mid-run, restart it, demand no losses
    let mut chaos_row = None;
    if args.switch("--chaos") {
        let n = replicas.max(2);
        println!("gateway-loadtest: chaos at {n} replicas (kill+restart replica 0 mid-run)…");
        let (fleet, gw, server, addr) = gw_stack(n)?;
        let srv = server.clone();
        let t = std::thread::spawn(move || srv.serve_forever());
        let fault = std::thread::spawn(move || {
            let mut fleet = fleet;
            std::thread::sleep(duration / 4);
            fleet.kill(0);
            std::thread::sleep(duration / 4);
            fleet.restart(0);
            fleet
        });
        let o = ama::bench::run_ama1_load_tolerant(addr, conns, duration, depth, &words, &opts_cycle);
        let fleet = fault.join().expect("fault-injection thread");
        let snap = gw.metrics().snapshot();
        println!("  chaos  : {o}");
        println!("  gateway: {snap}");
        anyhow::ensure!(
            o.errors == 0 && o.reorders == 0,
            "chaos run lost or corrupted replies: {} errors, {} reorders",
            o.errors,
            o.reorders
        );
        anyhow::ensure!(
            snap.breaker_opened >= 1 && snap.breaker_closed >= 1,
            "chaos run never exercised the breaker: {snap:?}"
        );
        println!(
            "  breaker tripped: opened={} half_opened={} closed={} failovers={} \
             typed_shed={}",
            snap.breaker_opened,
            snap.breaker_half_opened,
            snap.breaker_closed,
            snap.failovers,
            o.typed_shed
        );
        println!(
            "  zero-loss OK: words={} errors=0 reorders=0 (shed replies were typed)",
            o.words
        );
        stop_stack(server, t, fleet)?;
        chaos_row = Some((o, snap));
    }

    if let Some(out_path) = args.flag("--out") {
        let mut json = String::new();
        json.push_str("{\n");
        json.push_str("  \"schema\": \"ama-gateway-v1\",\n");
        json.push_str("  \"pr\": 7,\n");
        json.push_str(&format!("  \"conns\": {conns},\n"));
        json.push_str(&format!("  \"secs\": {secs},\n"));
        json.push_str(&format!("  \"depth\": {depth},\n"));
        json.push_str(&format!("  \"gateway_p50_overhead\": {overhead_p50:.4},\n"));
        json.push_str(&format!(
            "  \"overhead\": {{\"direct_wps\": {:.1}, \"gateway_wps\": {:.1}, \
             \"direct_p50_us\": {}, \"gateway_p50_us\": {}}},\n",
            direct.wps(),
            gated.wps(),
            direct.rtt_p50_us,
            gated.rtt_p50_us
        ));
        json.push_str("  \"scaling\": [\n");
        for (i, (n, o)) in scaling.iter().enumerate() {
            json.push_str(&format!(
                "    {{\"replicas\": {n}, \"wps\": {:.1}, \"rtt_p50_us\": {}, \
                 \"rtt_p99_us\": {}, \"errors\": {}}}{}\n",
                o.wps(),
                o.rtt_p50_us,
                o.rtt_p99_us,
                o.errors,
                if i + 1 < scaling.len() { "," } else { "" }
            ));
        }
        json.push_str("  ],\n");
        match &chaos_row {
            Some((o, snap)) => json.push_str(&format!(
                "  \"chaos\": {{\"words\": {}, \"errors\": {}, \"reorders\": {}, \
                 \"typed_shed\": {}, \"breaker_opened\": {}, \"breaker_half_opened\": {}, \
                 \"breaker_closed\": {}, \"failovers\": {}, \"coalesced_words\": {}}}\n",
                o.words,
                o.errors,
                o.reorders,
                o.typed_shed,
                snap.breaker_opened,
                snap.breaker_half_opened,
                snap.breaker_closed,
                snap.failovers,
                snap.coalesced_words
            )),
            None => json.push_str("  \"chaos\": null\n"),
        }
        json.push_str("}\n");
        std::fs::write(out_path, &json).with_context(|| format!("writing {out_path}"))?;
        println!("wrote {out_path}");
    }
    Ok(())
}

/// The analyze options shared by `ama index` / `ama search`: voting by
/// default (the accuracy harness engine), `--algo`/`--no-infix` override.
fn retrieval_opts(args: &Args) -> Result<AnalyzeOptions> {
    let algorithm = match args.flag("--algo") {
        None => Algorithm::Voting,
        Some(name) => Algorithm::from_name(name)
            .ok_or_else(|| anyhow!("unknown --algo {name:?} (linguistic|khoja|light|voting)"))?,
    };
    Ok(AnalyzeOptions {
        algorithm,
        infix: if args.switch("--no-infix") { Some(false) } else { None },
        want_trace: false,
    })
}

fn print_accuracy_line(rep: &ama::eval::AccuracyReport) {
    println!(
        "accuracy {:<24} roots {}/{} = {:.1}%  words {}/{} = {:.1}%  \
         (paper ref: quran-infix 87.7%, ankabut 90.7%)",
        rep.stemmer,
        rep.roots_recovered,
        rep.roots_present,
        100.0 * rep.root_accuracy(),
        rep.words_correct,
        rep.words_total,
        100.0 * rep.word_accuracy()
    );
}

/// `ama index` (PR 8): run the staged document pipeline (tokenize →
/// segment → batch analyze → optional context re-rank) over the inputs
/// and write an `AMAIDX01` snapshot. Inputs are text files, directories
/// of them, or a named synthetic corpus (`corpus:quran`,
/// `corpus:ankabut`, `corpus:small:N`). Analysis goes through a real
/// coordinator handle, so indexing exercises the same batching machinery
/// as `ama serve`; corpus inputs carry gold roots, so the run ends with
/// the accuracy harness against the paper's reference points.
fn cmd_index(args: &Args) -> Result<()> {
    use ama::index::{self, pipeline::{AnalyzeVia, DocUnit, PipelineConfig}};

    let inputs = &args.positionals[1..];
    anyhow::ensure!(
        !inputs.is_empty(),
        "usage: ama index <dir|file|corpus:NAME…> [--out IDX] [--doc-words N] [--rerank]"
    );
    let out = args.flag_or("--out", "ama.idx").to_string();
    let doc_words = args.flag_usize("--doc-words", 64).map_err(|e| anyhow!(e))?.max(1);
    let roots = load_roots(args)?;
    let opts = retrieval_opts(args)?;
    let pipe_cfg = PipelineConfig {
        workers: args.flag_usize("--workers", 2).map_err(|e| anyhow!(e))?.max(1),
        opts,
        rerank: args.switch("--rerank"),
        window: args.flag_usize("--window", 3).map_err(|e| anyhow!(e))?.max(1),
        ..PipelineConfig::default()
    };

    // Gather documents. `corpus:` inputs keep the full Corpus around for
    // the gold-scored accuracy harness.
    let mut units: Vec<DocUnit> = Vec::new();
    let mut gold_corpus: Option<ama::corpus::Corpus> = None;
    if let Some(spec) = inputs[0].strip_prefix("corpus:") {
        anyhow::ensure!(inputs.len() == 1, "corpus: input cannot be mixed with file inputs");
        let ccfg = match spec {
            "quran" => CorpusConfig::quran(),
            "ankabut" => CorpusConfig::ankabut(),
            other => match other.strip_prefix("small:") {
                Some(n) => CorpusConfig::small(
                    n.parse().map_err(|_| anyhow!("corpus:small:N — invalid N {n:?}"))?,
                    args.flag_u64("--seed", 1).map_err(|e| anyhow!(e))?,
                ),
                None => bail!("unknown corpus {other:?} (quran|ankabut|small:N)"),
            },
        };
        let c = corpus::generate(&roots, &ccfg);
        println!("{}", report::corpus_stats_line(&c));
        units = index::corpus_units(&c, doc_words);
        gold_corpus = Some(c);
    } else {
        let mut paths: Vec<PathBuf> = Vec::new();
        for input in inputs {
            let p = PathBuf::from(input);
            if p.is_dir() {
                let mut entries: Vec<PathBuf> = std::fs::read_dir(&p)
                    .with_context(|| format!("reading directory {input}"))?
                    .filter_map(|e| e.ok().map(|e| e.path()))
                    .filter(|p| p.is_file())
                    .collect();
                entries.sort();
                paths.extend(entries);
            } else {
                paths.push(p);
            }
        }
        anyhow::ensure!(!paths.is_empty(), "no input files found");
        for (i, p) in paths.iter().enumerate() {
            let text = std::fs::read_to_string(p)
                .with_context(|| format!("reading {}", p.display()))?;
            let name = p
                .file_name()
                .map(|n| n.to_string_lossy().into_owned())
                .unwrap_or_else(|| format!("doc-{i}"));
            units.push(DocUnit::from_text(i as u32, name, text));
        }
    }

    // Analysis through a real coordinator: `ama index` is the bulk-write
    // counterpart of `ama serve`, on the same batching machinery.
    let coord = start_coordinator(
        args,
        args.flag_or("--backend", "registry"),
        roots.clone(),
        opts.infix.unwrap_or(true),
        CoordinatorConfig::default(),
    )?;
    let via = AnalyzeVia::Coordinator(coord.handle());
    let voting = pipe_cfg.rerank.then(|| ama::light::VotingAnalyzer::new(roots.clone()));
    let stages = index::pipeline::build_stages(via.clone(), &pipe_cfg, voting);
    let run = index::pipeline::run(stages, units, &pipe_cfg);

    let idx = index::index_from_run(&run);
    let stats = idx.stats();
    let dropped: u64 = run.docs.iter().map(|d| u64::from(d.dropped)).sum();
    println!(
        "indexed {} docs, {} words ({} non-Arabic tokens dropped) -> {} postings over \
         {} distinct roots, {} surface forms",
        stats.docs, stats.words_seen, dropped, stats.postings, stats.distinct_roots, stats.forms
    );
    println!(
        "pipeline throughput: {:.0} words/sec ({} words in {:.3}s)",
        run.wps(),
        run.words_total,
        run.elapsed.as_secs_f64()
    );
    for s in &run.stages {
        println!(
            "  stage {:>8}: {:>6} docs  {:>8} words out  busy {:.3}s",
            s.name,
            s.units,
            s.words_out,
            s.busy_nanos as f64 / 1e9
        );
    }
    index::snapshot::save(&idx, Path::new(&out))?;
    let bytes = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!("wrote {out} ({bytes} bytes, AMAIDX01)");

    if let Some(c) = gold_corpus {
        let (base, rr) = index::accuracy_harness(via, &roots, &c, &pipe_cfg, doc_words);
        print_accuracy_line(&base);
        print_accuracy_line(&rr);
    }
    coord.shutdown();
    Ok(())
}

/// `ama search` (PR 8): load an `AMAIDX01` snapshot, analyze the query
/// words to roots, intersect postings (strict AND), and print ranked
/// hits with surface-form contexts.
fn cmd_search(args: &Args) -> Result<()> {
    use ama::index;

    anyhow::ensure!(
        args.positionals.len() >= 3,
        "usage: ama search IDX <words…> [--top K] [--algo …] [--no-infix]"
    );
    let idx_path = &args.positionals[1];
    let query = &args.positionals[2..];
    let idx = index::snapshot::load(Path::new(idx_path))?;
    let stats = idx.stats();
    println!(
        "loaded {idx_path}: {} docs, {} distinct roots, {} postings",
        stats.docs, stats.distinct_roots, stats.postings
    );

    let opts = retrieval_opts(args)?;
    let registry = AnalyzerRegistry::new(load_roots(args)?);
    let packed: Vec<ama::chars::PackedWord> =
        query.iter().map(|w| ama::chars::PackedWord::encode(w)).collect();
    for (w, p) in query.iter().zip(&packed) {
        anyhow::ensure!(p.has_arabic(), "query word {w:?} has no Arabic letters");
    }
    let (keys, unrooted) = index::query_roots(&registry, &packed, &opts);
    for &i in &unrooted {
        eprintln!("note: no root extracted for query word {:?} — ignored", query[i]);
    }
    anyhow::ensure!(!keys.is_empty(), "no query word produced a root");
    let roots_str: Vec<String> = keys.iter().map(|&k| index::key_root(k).to_string_ar()).collect();
    println!("query roots: {}", roots_str.join(" "));

    let top = args.flag_usize("--top", 10).map_err(|e| anyhow!(e))?.max(1);
    let hits = idx.search(&keys, top);
    let occurrences: u64 = hits.iter().map(|h| h.score).sum();
    println!("exact root hits: {} docs ({occurrences} occurrences)", hits.len());
    for h in &hits {
        println!("  doc {:<5} {:<24} score={} matched_roots={}", h.doc, h.name, h.score, h.matched_roots);
        for c in &h.contexts {
            println!(
                "      root {}  pos {:<5} form {}  confidence {:.2}",
                c.root, c.pos, c.form, c.confidence
            );
        }
    }
    Ok(())
}

/// Cross-validate all backends word-for-word on a generated corpus — the
/// strongest "all layers compose" check available from the CLI.
fn cmd_selftest(args: &Args) -> Result<()> {
    let roots = load_roots(args)?;
    let n = args.flag_usize("--words", 2000).map_err(|e| anyhow!(e))?;
    let c = corpus::generate(&roots, &CorpusConfig::small(n, 7));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();

    let sw = Stemmer::with_defaults(roots.clone());
    let expected = sw.stem_batch(&words);

    // SIMD kernel vs the pinned scalar packed kernel (PR 6). The batch
    // API may already dispatch to SIMD; this cross-checks every stage
    // explicitly so `AMA_SIMD` overrides are validated end to end.
    let packed: Vec<ama::chars::PackedWord> =
        words.iter().map(ama::chars::PackedWord::pack).collect();
    let scalar_res = sw.stem_batch_packed_scalar(&packed);
    anyhow::ensure!(scalar_res == expected, "scalar packed kernel diverged from stem_batch");
    let simd_path = ama::simd::active().unwrap_or_else(ama::simd::best_available);
    let simd_res = sw.stem_batch_simd(&packed);
    anyhow::ensure!(simd_res == expected, "simd kernel diverged from the scalar packed kernel");
    println!(
        "simd kernel: OK ({n} words via {}, bit-identical to scalar kernel)",
        simd_path.name()
    );

    // HW simulators (with infix units, matching the software default)
    use ama::hw::Processor as _;
    let cfg = DatapathConfig { infix_units: true };
    let (np_res, _) = NonPipelinedProcessor::new(roots.clone(), cfg).run(&words);
    let (pp_res, _) = PipelinedProcessor::new(roots.clone(), cfg).run(&words);
    anyhow::ensure!(np_res == expected, "non-pipelined simulator diverged from software");
    anyhow::ensure!(pp_res == expected, "pipelined simulator diverged from software");
    println!("hw simulators: OK ({n} words, bit-identical to software)");

    // Runtime engine (HLO interpreter by default, PJRT with the feature)
    let artifacts = artifacts_dir(args);
    if artifacts.join("stemmer_b1.hlo.txt").exists() {
        let engine = Engine::load(&artifacts, &roots)?;
        let rt_res = engine.stem_chunk(&words)?;
        let mut mismatches = 0;
        for (i, (a, b)) in rt_res.iter().zip(&expected).enumerate() {
            if a != b {
                if mismatches < 5 {
                    eprintln!(
                        "word {} ({}): runtime {:?} vs software {:?}",
                        i,
                        words[i],
                        a,
                        b
                    );
                }
                mismatches += 1;
            }
        }
        anyhow::ensure!(mismatches == 0, "{mismatches} runtime-engine mismatches");
        // …and against the retained scalar specification, so the
        // artifact cycle is pinned to the executable spec end to end.
        for (i, (a, w)) in rt_res.iter().zip(&words).enumerate() {
            anyhow::ensure!(
                *a == sw.stem_reference(w),
                "word {i} ({w}): runtime engine diverged from stem_reference"
            );
        }
        println!(
            "runtime engine: OK ({n} words via {}, bit-identical to software + reference)",
            engine.backend_name()
        );
    } else {
        println!(
            "runtime engine: SKIPPED (no artifacts — run `make artifacts` or `ama emit-hlo`)"
        );
    }
    Ok(())
}
