//! Accuracy evaluation — Tables 6 and 7 of the paper.
//!
//! The paper's Table 6 metric is *root-level*: of the roots extractable
//! from the corpus, how many did the stemmer recover (from at least one
//! occurrence)? 1,549/1,767 = 87.7% with infix processing, 1,261/1,767 =
//! 71.3% without. Table 7 is *occurrence-level* for the ten most frequent
//! roots, compared against the Khoja stemmer. Our synthetic corpus carries
//! exact gold roots (DESIGN.md §5), so both metrics are computed exactly.

use crate::chars::ArabicWord;
use crate::corpus::Corpus;
use crate::stemmer::StemResult;
use std::collections::HashSet;

/// Root-level + word-level accuracy of one stemmer over one corpus.
#[derive(Clone, Debug)]
pub struct AccuracyReport {
    pub corpus: String,
    pub stemmer: String,
    /// Distinct gold roots present in the corpus.
    pub roots_present: usize,
    /// Distinct gold roots recovered from ≥1 occurrence (Table 6 count).
    pub roots_recovered: usize,
    /// Token-level: occurrences whose extracted root equals gold.
    pub words_total: usize,
    pub words_correct: usize,
}

impl AccuracyReport {
    /// Table 6 accuracy (root-level).
    pub fn root_accuracy(&self) -> f64 {
        if self.roots_present == 0 {
            return 0.0;
        }
        self.roots_recovered as f64 / self.roots_present as f64
    }

    pub fn word_accuracy(&self) -> f64 {
        if self.words_total == 0 {
            return 0.0;
        }
        self.words_correct as f64 / self.words_total as f64
    }
}

fn root_eq(result: &StemResult, gold: &[u16; 4]) -> bool {
    result.root == *gold
}

/// Evaluate a batch stemming function over a corpus.
pub fn evaluate<F>(corpus: &Corpus, stemmer_name: &str, mut stem_fn: F) -> AccuracyReport
where
    F: FnMut(&[ArabicWord]) -> Vec<StemResult>,
{
    let words: Vec<ArabicWord> = corpus.tokens.iter().map(|t| t.word).collect();
    let results = stem_fn(&words);
    assert_eq!(results.len(), words.len(), "stemmer returned wrong count");

    let mut present: HashSet<[u16; 4]> = HashSet::new();
    let mut recovered: HashSet<[u16; 4]> = HashSet::new();
    let mut words_correct = 0usize;
    for (tok, res) in corpus.tokens.iter().zip(&results) {
        present.insert(tok.gold);
        if root_eq(res, &tok.gold) {
            recovered.insert(tok.gold);
            words_correct += 1;
        }
    }
    AccuracyReport {
        corpus: corpus.name.clone(),
        stemmer: stemmer_name.to_string(),
        roots_present: present.len(),
        roots_recovered: recovered.len(),
        words_total: corpus.tokens.len(),
        words_correct,
    }
}

/// One Table 7 row: occurrence counts for a specific root.
#[derive(Clone, Debug)]
pub struct RootFrequencyRow {
    pub root: ArabicWord,
    /// Gold occurrences in the corpus ("Actual" column).
    pub actual: usize,
    /// Occurrences each stemmer attributed to this root *correctly*.
    pub counts: Vec<usize>,
}

/// Occurrence-level per-root comparison across several stemmers
/// (Table 7: Actual / Khoja / proposed-with-infix / proposed-without).
pub fn per_root_frequency(
    corpus: &Corpus,
    roots_of_interest: &[ArabicWord],
    stemmers: &mut [(&str, Box<dyn FnMut(&[ArabicWord]) -> Vec<StemResult> + '_>)],
) -> Vec<RootFrequencyRow> {
    let words: Vec<ArabicWord> = corpus.tokens.iter().map(|t| t.word).collect();
    let all_results: Vec<Vec<StemResult>> =
        stemmers.iter_mut().map(|(_, f)| f(&words)).collect();

    let mut rows = Vec::new();
    for r in roots_of_interest {
        let mut gold = [0u16; 4];
        gold[..r.len.min(4)].copy_from_slice(&r.chars[..r.len.min(4)]);
        let actual = corpus.tokens.iter().filter(|t| t.gold == gold).count();
        let counts = all_results
            .iter()
            .map(|res| {
                corpus
                    .tokens
                    .iter()
                    .zip(res)
                    .filter(|(t, s)| t.gold == gold && root_eq(s, &gold))
                    .count()
            })
            .collect();
        rows.push(RootFrequencyRow { root: *r, actual, counts });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::corpus::{generate, CorpusConfig};
    use crate::roots::RootSet;
    use crate::stemmer::{Stemmer, StemmerConfig};
    use std::sync::Arc;

    #[test]
    fn perfect_stemmer_scores_one() {
        let roots = Arc::new(RootSet::builtin_mini());
        let c = generate(&roots, &CorpusConfig::small(200, 1));
        // cheat: return gold directly
        let golds: Vec<_> = c.tokens.iter().map(|t| t.gold).collect();
        let mut i = 0;
        let rep = evaluate(&c, "oracle", |ws| {
            let out = ws
                .iter()
                .map(|_| {
                    let g = golds[i];
                    i += 1;
                    StemResult { root: g, kind: crate::stemmer::MatchKind::Tri, cut: 0 }
                })
                .collect();
            out
        });
        assert_eq!(rep.word_accuracy(), 1.0);
        assert_eq!(rep.root_accuracy(), 1.0);
    }

    #[test]
    fn infix_processing_improves_accuracy() {
        // The Table 6 phenomenon, on a small corpus.
        let roots = Arc::new(RootSet::builtin_mini());
        let c = generate(&roots, &CorpusConfig::small(2000, 2));
        let with = Stemmer::with_defaults(roots.clone());
        let without = Stemmer::new(roots.clone(), StemmerConfig { infix_processing: false });
        let rep_with = evaluate(&c, "with-infix", |ws| with.stem_batch(ws));
        let rep_without = evaluate(&c, "no-infix", |ws| without.stem_batch(ws));
        assert!(
            rep_with.word_accuracy() > rep_without.word_accuracy() + 0.05,
            "with {:.3} vs without {:.3}",
            rep_with.word_accuracy(),
            rep_without.word_accuracy()
        );
        assert!(rep_with.roots_recovered >= rep_without.roots_recovered);
    }

    #[test]
    fn per_root_rows() {
        let roots = Arc::new(RootSet::builtin_mini());
        let c = generate(&roots, &CorpusConfig::small(500, 3));
        let with = Stemmer::with_defaults(roots.clone());
        let interest = vec![ArabicWord::encode("درس"), ArabicWord::encode("قول")];
        let mut stemmers: Vec<(&str, Box<dyn FnMut(&[ArabicWord]) -> Vec<StemResult>>)> =
            vec![("with", Box::new(|ws: &[ArabicWord]| with.stem_batch(ws)))];
        let rows = per_root_frequency(&c, &interest, &mut stemmers);
        assert_eq!(rows.len(), 2);
        for row in &rows {
            assert!(row.counts[0] <= row.actual, "correct > actual for {}", row.root);
        }
    }
}
