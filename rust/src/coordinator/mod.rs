//! L3 coordinator: dynamic batcher + worker pool + backpressure — the
//! serving organization around the stemmer backends.
//!
//! The paper's pipelined processor overlaps five datapath stages so a new
//! word enters every clock; its headline 5571× speedup comes from the
//! *organization around* the datapath as much as the datapath itself. The
//! serving analog keeps every stage busy with zero per-word allocation:
//!
//! * **Intake** — requests stream into a bounded [`BoundedQueue`]
//!   (backpressure: producers stall when the queue fills, exactly like the
//!   paper's pipeline stalling its front end). Since PR 3 every
//!   [`Request`] carries an [`EngineOpts`] *options word* (algorithm,
//!   infix override, trace bit packed into one byte), so the algorithm is
//!   a per-request parameter instead of a compile-time backend choice.
//!   Since PR 4 the word itself rides as a [`PackedWord`] `u128` register
//!   (packed at the `Handle` boundary), so a whole request is ~32 bytes
//!   of plain data and workers dispatch through
//!   [`StemBackend::analyze_batch_packed`] without re-encoding.
//!   The cache-fronted [`RegistryBackend`] probes a shared
//!   [`StemCache`] before kernel dispatch — repeated surface forms (the
//!   common case in real Arabic text) are answered by one lock-free load.
//! * **Batching** — a dynamic batcher groups whatever is waiting (up to
//!   `max_batch`, with a `max_wait` deadline) and hands it to a worker
//!   running a pluggable [`StemBackend`]. A popped batch is partitioned
//!   by options word (uniform batches — the common case — stay one
//!   group), and each group dispatches through
//!   [`StemBackend::analyze_batch_opts`]; the [`RegistryBackend`] routes
//!   groups to the four [`Analyzer`] engines, so one coordinator serves
//!   linguistic/khoja/light/voting traffic concurrently.
//! * **Reply routing** — instead of one `mpsc::channel()` allocation per
//!   word (PR 1's hot-path residue), every request carries a `ticket`
//!   into a shared [`exec::ReplySlab`]: a fixed-capacity, index-addressed
//!   slab of reusable reply slots with park/unpark wakeups. Workers
//!   `fill(ticket, analysis)`; submitters `wait(ticket)`. The slab
//!   machinery is unchanged from PR 2 — only its payload grew from a bare
//!   `StemResult` to an [`Analysis`] (still allocation-free unless a
//!   trace was requested). The steady-state submit → stem → reply cycle
//!   allocates nothing.
//!
//! [`Handle::stem_bulk`] / [`Handle::stem_stream`] / [`Handle::analyze_bulk`]
//! share a *windowed* submit/collect core: up to half the slab may be in
//! flight per call, and when the slab runs dry the submitter reaps its own
//! oldest reply before acquiring more — so arbitrarily large streams
//! pipeline through the fixed slab without deadlock, preserving
//! submission order throughout.
//!
//! Failures on the request path are typed (PR 3): [`ServeError`] carries
//! the same [`ErrorCode`]s the AMA/1 wire protocol speaks (`SHUTDOWN`,
//! `QUEUE_FULL`, `TIMEOUT`, …) and each rejection is counted in
//! [`ServiceMetrics`].
//!
//! Backends are constructed *on* their worker thread via a factory, which
//! is what lets the `Rc`-based PJRT engine participate without being
//! `Send`.
//!
//! [`Analyzer`]: crate::analysis::Analyzer

use crate::analysis::{
    Algorithm, Analysis, AnalyzerRegistry, EngineOpts, ErrorCode, ServeError,
};
use crate::cache::{StemCache, DEFAULT_CACHE_SLOTS};
use crate::chars::{ArabicWord, PackedWord};
use crate::exec::{BoundedQueue, QueueError, ReplySlab, WorkerPool};
use crate::metrics::ServiceMetrics;
use crate::roots::RootSet;
use crate::stemmer::{StemResult, StemmerConfig};
use anyhow::Result;
use std::collections::VecDeque;
// Concurrency facade (PR 10): std re-exports in normal builds, the chk
// model-checker instrumentation under `--features chk`.
use crate::chk::sync::atomic::Ordering;
use crate::chk::sync::Arc;
use crate::chk::time::Instant;
use std::time::Duration;

/// A batch-oriented root-extraction backend.
pub trait StemBackend {
    fn name(&self) -> &'static str;

    /// Which engine this backend actually runs — the label stamped onto
    /// results by the default [`StemBackend::analyze_batch_opts`], so
    /// wire replies never claim an algorithm the backend didn't execute.
    fn algorithm(&self) -> Algorithm {
        Algorithm::Linguistic
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>>;

    /// Options-aware batch (PR 3). The default ignores the options word —
    /// a compile-time single-engine backend (`hw-sim`, `runtime`, a
    /// dedicated khoja worker) made its choice at startup, so per-request
    /// algorithm/infix/trace selectors are no-ops there and results are
    /// labeled with [`StemBackend::algorithm`] (the engine that really
    /// answered; clients can detect the mismatch from the reply's `algo`
    /// field). The [`RegistryBackend`] overrides this to genuinely route
    /// per request.
    fn analyze_batch_opts(
        &mut self,
        words: &[ArabicWord],
        _opts: EngineOpts,
    ) -> Result<Vec<Analysis>> {
        let algorithm = self.algorithm();
        Ok(self
            .stem_batch(words)?
            .into_iter()
            .map(|r| Analysis::from_result(r, algorithm))
            .collect())
    }

    /// Packed-batch dispatch (PR 4) — what the coordinator's workers
    /// actually call, since every queued [`Request`] carries a
    /// [`PackedWord`]. The default unpacks at this boundary and forwards
    /// to [`StemBackend::analyze_batch_opts`], so existing backends work
    /// unchanged; packed-native backends ([`SoftwareBackend`],
    /// [`RegistryBackend`]) override to keep the words in registers.
    fn analyze_batch_packed(
        &mut self,
        words: &[PackedWord],
        opts: EngineOpts,
    ) -> Result<Vec<Analysis>> {
        let unpacked: Vec<ArabicWord> = words.iter().map(|w| w.unpack()).collect();
        self.analyze_batch_opts(&unpacked, opts)
    }
}

/// Constructs a backend on the worker thread (worker id passed in).
pub type BackendFactory = Box<dyn Fn(usize) -> Result<Box<dyn StemBackend>> + Send + Sync>;

/// One queued request: the word in its packed register form (PR 4 — 16
/// bytes instead of the 32-byte `ArabicWord`, shrinking every queue slot
/// and the per-request copy), the reply-slab ticket its result is routed
/// to, and the packed per-request options word. Plain data, no heap, no
/// per-request channel.
struct Request {
    word: PackedWord,
    submitted: Instant,
    ticket: u32,
    opts: EngineOpts,
}

/// Batching/queueing policy.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Maximum words per dispatched batch.
    pub max_batch: usize,
    /// How long the batcher waits for the first word of a batch.
    pub max_wait: Duration,
    /// Bounded request-queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// Number of backend workers.
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
            workers: 1,
        }
    }
}

impl CoordinatorConfig {
    /// Reply-slab capacity: everything that can be in flight at once —
    /// the full request queue plus one max-size batch per worker — with
    /// headroom for submitters between `acquire` and `push`.
    fn reply_slots(&self) -> usize {
        self.queue_capacity + self.workers * self.max_batch + 64
    }
}

/// The running coordinator.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Request>>,
    slab: Arc<ReplySlab<Analysis>>,
    pool: Option<WorkerPool>,
    metrics: Arc<ServiceMetrics>,
}

impl Coordinator {
    /// Start workers, each owning a backend built by `factory`.
    pub fn start(cfg: CoordinatorConfig, factory: BackendFactory) -> Self {
        Self::start_with_metrics(cfg, factory, Arc::new(ServiceMetrics::new()))
    }

    /// [`Coordinator::start`] with caller-supplied metrics, so a factory
    /// (e.g. a cache-counting [`RegistryBackend`]) can share the same
    /// [`ServiceMetrics`] the coordinator reports from.
    pub fn start_with_metrics(
        cfg: CoordinatorConfig,
        factory: BackendFactory,
        metrics: Arc<ServiceMetrics>,
    ) -> Self {
        let queue: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.queue_capacity);
        let slab: Arc<ReplySlab<Analysis>> = ReplySlab::new(cfg.reply_slots());
        let q = queue.clone();
        let s = slab.clone();
        let m = metrics.clone();
        let factory = Arc::new(factory);
        let failed_inits = Arc::new(crate::chk::sync::AtomicUsize::new(0));
        let pool = WorkerPool::spawn(cfg.workers, "stem-worker", move |id, _sd| {
            let mut backend = match factory(id) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("worker {id}: backend init failed: {e:#}");
                    m.errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                    // If EVERY worker failed init, nobody will ever pop the
                    // queue — the last worker to fail runs a reject loop so
                    // a live serve process degrades loudly (NONE replies)
                    // instead of parking every client forever. With any
                    // healthy sibling, just exit and let it serve 100%.
                    // ord: Relaxed — a pure counter; the RMW's atomicity
                    // (not its ordering) guarantees exactly one worker
                    // observes the final count. Was SeqCst.
                    // ord: Relaxed — statistics counter; no ordering required.
                    if failed_inits.fetch_add(1, Ordering::Relaxed) + 1 == cfg.workers {
                        while let Ok(req) = q.pop() {
                            m.errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                            s.fill(req.ticket, Analysis::none(req.opts.algorithm()));
                        }
                    }
                    return;
                }
            };
            let mut words: Vec<PackedWord> = Vec::with_capacity(cfg.max_batch);
            // Option-group scratch, reused across batches. A popped batch
            // is partitioned by its packed options word; uniform batches
            // (the overwhelmingly common case) form exactly one group.
            let mut distinct: Vec<EngineOpts> = Vec::new();
            let mut group_idx: Vec<usize> = Vec::with_capacity(cfg.max_batch);
            loop {
                let batch = match q.pop_batch(cfg.max_batch, cfg.max_wait) {
                    Ok(b) => b,
                    Err(QueueError::Timeout) => continue,
                    Err(_) => break, // closed and drained
                };
                distinct.clear();
                for r in &batch {
                    if !distinct.contains(&r.opts) {
                        distinct.push(r.opts);
                    }
                }
                for &opts in &distinct {
                    group_idx.clear();
                    group_idx.extend(
                        batch.iter().enumerate().filter(|(_, r)| r.opts == opts).map(|(i, _)| i),
                    );
                    words.clear();
                    words.extend(group_idx.iter().map(|&i| batch[i].word));
                    // Every popped ticket MUST be filled, whatever the
                    // backend does — a panic or a short result vector would
                    // otherwise leave waiters parked forever (the old mpsc
                    // design woke them via dropped Senders; the slab has no
                    // such tripwire).
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        backend.analyze_batch_packed(&words, opts)
                    }));
                    let results = match outcome {
                        Ok(Ok(results)) if results.len() == words.len() => Some(results),
                        Ok(Ok(results)) => {
                            eprintln!(
                                "worker {id}: backend returned {} results for {} words",
                                results.len(),
                                words.len()
                            );
                            None
                        }
                        Ok(Err(e)) => {
                            eprintln!("worker {id}: batch failed: {e:#}");
                            None
                        }
                        Err(_) => {
                            eprintln!("worker {id}: backend panicked; failing the batch");
                            None
                        }
                    };
                    match results {
                        Some(results) => {
                            m.record_batch(words.len() as u64);
                            m.record_algorithm_words(opts.algorithm(), words.len() as u64);
                            for (&i, res) in group_idx.iter().zip(results) {
                                m.record_latency(batch[i].submitted.elapsed());
                                s.fill(batch[i].ticket, res);
                            }
                        }
                        None => {
                            m.errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                            for &i in &group_idx {
                                s.fill(batch[i].ticket, Analysis::none(opts.algorithm()));
                            }
                        }
                    }
                }
            }
        });
        Coordinator { queue, slab, pool: Some(pool), metrics }
    }

    /// Start a multi-engine coordinator: every worker hosts an
    /// [`AnalyzerRegistry`] behind a [`RegistryBackend`], so one running
    /// process answers per-request `algorithm`/`infix`/`trace` options
    /// for all four engines. `cfg_stemmer` sets the linguistic engine's
    /// *default* infix behavior (per-request options still override it).
    ///
    /// Serves through a default-sized shared [`StemCache`]
    /// ([`DEFAULT_CACHE_SLOTS`]); use
    /// [`Coordinator::start_registry_cached`] to size or disable it.
    pub fn start_registry(
        cfg: CoordinatorConfig,
        roots: Arc<RootSet>,
        cfg_stemmer: StemmerConfig,
    ) -> Self {
        Self::start_registry_cached(cfg, roots, cfg_stemmer, DEFAULT_CACHE_SLOTS)
    }

    /// [`Coordinator::start_registry`] with an explicit stem-cache size
    /// (the `--cache-slots` knob; `0` disables caching entirely). One
    /// cache is shared by every worker, so a form analyzed on any worker
    /// is a hit on all of them; `cache_hits`/`cache_misses` land in this
    /// coordinator's [`ServiceMetrics`].
    pub fn start_registry_cached(
        cfg: CoordinatorConfig,
        roots: Arc<RootSet>,
        cfg_stemmer: StemmerConfig,
        cache_slots: usize,
    ) -> Self {
        let metrics = Arc::new(ServiceMetrics::new());
        let cache = (cache_slots > 0).then(|| StemCache::new(cache_slots));
        let factory = registry_factory_cached(roots, cfg_stemmer, cache, Some(metrics.clone()));
        Self::start_with_metrics(cfg, factory, metrics)
    }

    pub fn handle(&self) -> Handle {
        Handle {
            queue: self.queue.clone(),
            slab: self.slab.clone(),
            metrics: self.metrics.clone(),
        }
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Owned handle on the metrics (e.g. for the `/metrics` endpoint's
    /// render closure, which outlives this borrow).
    pub fn metrics_arc(&self) -> Arc<ServiceMetrics> {
        self.metrics.clone()
    }

    /// Graceful shutdown: stop intake, drain, join workers.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.queue.close();
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        // If a worker died before draining (e.g. backend init failure),
        // requests may be stranded in the queue with waiters parked on
        // their tickets. Fail them instead of leaving replies in flight.
        while let Ok(req) = self.queue.pop_timeout(Duration::ZERO) {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
            self.slab.fill(req.ticket, Analysis::none(req.opts.algorithm()));
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Cheap, cloneable client handle.
#[derive(Clone)]
pub struct Handle {
    queue: Arc<BoundedQueue<Request>>,
    slab: Arc<ReplySlab<Analysis>>,
    metrics: Arc<ServiceMetrics>,
}

/// A pending reply: a live reply-slab ticket. Dropping it un-waited
/// abandons the ticket (the slot recycles when the worker fills it).
pub struct Pending {
    slab: Arc<ReplySlab<Analysis>>,
    ticket: u32,
    done: bool,
}

impl Pending {
    /// Block until the reply arrives.
    pub fn wait(mut self) -> Analysis {
        self.done = true;
        self.slab.wait(self.ticket)
    }

    /// [`wait`](Pending::wait) with a deadline; expiry is the typed
    /// `TIMEOUT` error (the ticket is abandoned and recycles when the
    /// late fill lands — capacity never leaks).
    pub fn wait_timeout(mut self, d: Duration) -> Result<Analysis, ServeError> {
        self.done = true;
        self.slab
            .wait_timeout(self.ticket, d)
            .map_err(|_| ServeError::new(ErrorCode::Timeout, format!("no reply within {d:?}")))
    }
}

impl Drop for Pending {
    fn drop(&mut self) {
        if !self.done {
            self.slab.abandon(self.ticket);
        }
    }
}

/// How long a failed bulk submission waits for already-accepted replies
/// before abandoning them (shutdown races resolve in microseconds; this
/// is a hang backstop, not a latency target).
const DRAIN_GRACE: Duration = Duration::from_secs(5);

impl Handle {
    /// Acquire a reply ticket, counting slab exhaustion as saturation.
    fn acquire_ticket(&self) -> u32 {
        match self.slab.try_acquire() {
            Some(t) => t,
            None => {
                self.metrics.slab_waits.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                self.slab.acquire()
            }
        }
    }

    /// Enqueue a request, counting a full queue as saturation. With a
    /// `submit_timeout`, a queue that stays full past the deadline fails
    /// typed (`Timeout` here → `QUEUE_FULL` at the caller) instead of
    /// blocking forever.
    fn enqueue(
        &self,
        req: Request,
        submit_timeout: Option<Duration>,
    ) -> Result<(), QueueError> {
        match self.queue.try_push(req) {
            Ok(()) => Ok(()),
            Err((req, QueueError::WouldBlock)) => {
                self.metrics.queue_full_events.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                match submit_timeout {
                    None => self.queue.push(req),
                    Some(t) => self.queue.push_timeout(req, t).map_err(|(_, e)| e),
                }
            }
            Err((_, e)) => Err(e),
        }
    }

    /// Map an enqueue failure to the typed protocol error, counting the
    /// rejection.
    fn rejection(&self, e: QueueError, context: String) -> ServeError {
        let code = match e {
            QueueError::Timeout => ErrorCode::QueueFull,
            _ => ErrorCode::Shutdown,
        };
        self.metrics.record_rejection(code);
        let msg = match code {
            ErrorCode::QueueFull => format!("request queue full: {context}"),
            _ => format!("coordinator closed: {context}"),
        };
        ServeError::new(code, msg)
    }

    /// Service metrics shared with the coordinator that issued this handle.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Submit one word at default options; blocks only if the queue or
    /// reply slab is full (backpressure). Allocation-free on the
    /// steady-state path.
    pub fn submit(&self, word: ArabicWord) -> Result<Pending, ServeError> {
        self.submit_opts(word, EngineOpts::default())
    }

    /// Submit one word with a per-request options word. Packs at the
    /// boundary (PR 4) — the queue and slab carry only the register form.
    pub fn submit_opts(&self, word: ArabicWord, opts: EngineOpts) -> Result<Pending, ServeError> {
        self.submit_packed_opts(PackedWord::pack(&word), opts)
    }

    /// Submit one already-packed word at default options.
    pub fn submit_packed(&self, word: PackedWord) -> Result<Pending, ServeError> {
        self.submit_packed_opts(word, EngineOpts::default())
    }

    /// Submit one already-packed word with a per-request options word —
    /// the native entry point every other submit path funnels into.
    pub fn submit_packed_opts(
        &self,
        word: PackedWord,
        opts: EngineOpts,
    ) -> Result<Pending, ServeError> {
        let ticket = self.acquire_ticket();
        let req = Request { word, submitted: Instant::now(), ticket, opts };
        match self.enqueue(req, None) {
            Ok(()) => Ok(Pending { slab: self.slab.clone(), ticket, done: false }),
            Err(e) => {
                // The request never reached a worker; recycle directly.
                self.slab.release_unused(ticket);
                Err(self.rejection(e, "request not accepted".to_string()))
            }
        }
    }

    /// Synchronous single-word convenience.
    pub fn stem(&self, word: ArabicWord) -> Result<StemResult, ServeError> {
        Ok(self.submit(word)?.wait().result)
    }

    /// Synchronous single-word analysis with options.
    pub fn analyze(&self, word: ArabicWord, opts: EngineOpts) -> Result<Analysis, ServeError> {
        Ok(self.submit_opts(word, opts)?.wait())
    }

    /// Bulk submission through the windowed core: submissions overlap
    /// execution and replies route through reusable slab slots — zero
    /// allocation per word, order preserved.
    pub fn stem_bulk(&self, words: &[ArabicWord]) -> Result<Vec<StemResult>, ServeError> {
        Ok(self
            .analyze_windowed(words.iter().map(PackedWord::pack), EngineOpts::default(), None)?
            .into_iter()
            .map(|a| a.result)
            .collect())
    }

    /// [`Handle::stem_bulk`] over already-packed words — the server's
    /// line-ingest path, which encodes UTF-8 straight into registers.
    pub fn stem_bulk_packed(&self, words: &[PackedWord]) -> Result<Vec<StemResult>, ServeError> {
        Ok(self
            .analyze_windowed(words.iter().copied(), EngineOpts::default(), None)?
            .into_iter()
            .map(|a| a.result)
            .collect())
    }

    /// Pipeline a whole slice through the coordinator, preserving order.
    /// Same windowed core as [`Handle::stem_bulk`] — the serving analog of
    /// the paper's pipelined processor keeping every stage busy.
    pub fn stem_stream(&self, words: &[ArabicWord]) -> Result<Vec<StemResult>, ServeError> {
        self.stem_bulk(words)
    }

    /// Bulk analysis under one options word (order preserved).
    pub fn analyze_bulk(
        &self,
        words: &[ArabicWord],
        opts: EngineOpts,
    ) -> Result<Vec<Analysis>, ServeError> {
        self.analyze_windowed(words.iter().map(PackedWord::pack), opts, None)
    }

    /// [`Handle::analyze_bulk`] over already-packed words.
    pub fn analyze_bulk_packed(
        &self,
        words: &[PackedWord],
        opts: EngineOpts,
    ) -> Result<Vec<Analysis>, ServeError> {
        self.analyze_windowed(words.iter().copied(), opts, None)
    }

    /// [`analyze_bulk`](Handle::analyze_bulk) with a per-word submission
    /// deadline: if the request queue stays full past `submit_timeout`,
    /// the call fails with the typed `QUEUE_FULL` error (already-accepted
    /// replies are drained first). This is the overload-shedding entry
    /// the AMA/1 protocol handler uses.
    pub fn analyze_bulk_deadline(
        &self,
        words: &[ArabicWord],
        opts: EngineOpts,
        submit_timeout: Duration,
    ) -> Result<Vec<Analysis>, ServeError> {
        self.analyze_windowed(words.iter().map(PackedWord::pack), opts, Some(submit_timeout))
    }

    /// [`Handle::analyze_bulk_deadline`] over already-packed words — the
    /// AMA/1 handler's entry (envelopes encode straight to registers).
    pub fn analyze_bulk_packed_deadline(
        &self,
        words: &[PackedWord],
        opts: EngineOpts,
        submit_timeout: Duration,
    ) -> Result<Vec<Analysis>, ServeError> {
        self.analyze_windowed(words.iter().copied(), opts, Some(submit_timeout))
    }

    /// Windowed submit/collect: keep up to `window` tickets in flight;
    /// when the slab runs dry, reap our own oldest reply (guaranteed to be
    /// filled eventually, since it was accepted by the queue) instead of
    /// deadlocking on capacity we ourselves are holding. Generic over a
    /// packed-word iterator so the `ArabicWord` entry points pack
    /// per-word with no intermediate buffer.
    fn analyze_windowed<I>(
        &self,
        words: I,
        opts: EngineOpts,
        submit_timeout: Option<Duration>,
    ) -> Result<Vec<Analysis>, ServeError>
    where
        I: ExactSizeIterator<Item = PackedWord>,
    {
        let total = words.len();
        let window = (self.slab.capacity() / 2).max(1);
        let submitted = Instant::now();
        let mut out: Vec<Analysis> = Vec::with_capacity(total);
        let mut inflight: VecDeque<u32> = VecDeque::with_capacity(window.min(total));
        for word in words {
            if inflight.len() >= window {
                let t = inflight.pop_front().expect("window non-empty");
                out.push(self.slab.wait(t));
            }
            let ticket = loop {
                if let Some(t) = self.slab.try_acquire() {
                    break t;
                }
                match inflight.pop_front() {
                    // Slab exhausted but we hold in-flight tickets: reap
                    // the oldest to free a slot.
                    Some(t) => out.push(self.slab.wait(t)),
                    // Nothing of ours in flight: block on other clients.
                    None => {
                        // ord: Relaxed — statistics counter; no ordering required.
                        self.metrics.slab_waits.fetch_add(1, Ordering::Relaxed);
                        break self.slab.acquire();
                    }
                }
            };
            let req = Request { word, submitted, ticket, opts };
            if let Err(e) = self.enqueue(req, submit_timeout) {
                self.slab.release_unused(ticket);
                // Partial-submit: the queue closed (or stayed full past
                // the deadline) mid-stream. Drain every already-accepted
                // reply (workers drain the queue even after close) so
                // nothing is left in flight, then report typed how far we
                // got.
                let accepted = out.len() + inflight.len();
                for t in inflight.drain(..) {
                    if let Ok(r) = self.slab.wait_timeout(t, DRAIN_GRACE) {
                        out.push(r);
                    }
                }
                return Err(self.rejection(
                    e,
                    format!(
                        "mid-stream: {accepted}/{total} words accepted, {} replies drained",
                        out.len()
                    ),
                ));
            }
            inflight.push_back(ticket);
        }
        for t in inflight.drain(..) {
            out.push(self.slab.wait(t));
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Backend implementations
// ---------------------------------------------------------------------------

/// The software stemmer as a backend. Batches go through the SoA fused
/// kernel (`Stemmer::stem_batch`): dense-index encoding, AffixProfile
/// candidate checks, direct-addressed dictionary bitsets. Honors
/// per-request infix/trace options through the `Analyzer` impl (the
/// algorithm selector is ignored — this backend *is* the linguistic
/// engine).
pub struct SoftwareBackend(pub crate::stemmer::Stemmer);

impl StemBackend for SoftwareBackend {
    fn name(&self) -> &'static str {
        "software"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.0.stem_batch(words))
    }

    fn analyze_batch_opts(
        &mut self,
        words: &[ArabicWord],
        opts: EngineOpts,
    ) -> Result<Vec<Analysis>> {
        use crate::analysis::Analyzer as _;
        Ok(self.0.analyze_batch(words, &opts.to_options()))
    }

    fn analyze_batch_packed(
        &mut self,
        words: &[PackedWord],
        opts: EngineOpts,
    ) -> Result<Vec<Analysis>> {
        Ok(self.0.analyze_batch_packed(words, &opts.to_options()))
    }
}

/// The software stemmer with intra-batch parallelism: large batches fan
/// out across an internal `exec::WorkerPool`
/// (`Stemmer::stem_batch_parallel`). Useful when the coordinator runs few
/// workers but receives large bulk batches.
pub struct ParallelSoftwareBackend {
    pub stemmer: crate::stemmer::Stemmer,
    pub threads: usize,
}

impl StemBackend for ParallelSoftwareBackend {
    fn name(&self) -> &'static str {
        "software-par"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.stemmer.stem_batch_parallel(words, self.threads))
    }
}

/// Either FPGA-simulator processor as a backend.
pub struct HwBackend<P: crate::hw::Processor>(pub P);

impl<P: crate::hw::Processor> StemBackend for HwBackend<P> {
    fn name(&self) -> &'static str {
        "hw-sim"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.0.run(words).0)
    }
}

/// The runtime [`Engine`] (HLO interpreter by default, PJRT with
/// `--features pjrt`) as a backend. The engine is **not** `Send`, so the
/// factory constructs it directly on the coordinator's worker thread —
/// that thread becomes the engine's dedicated executor, exactly the
/// ownership model the PJRT client requires (`ama serve --backend
/// runtime`).
///
/// [`Engine`]: crate::runtime::Engine
pub struct RuntimeBackend(pub crate::runtime::Engine);

impl StemBackend for RuntimeBackend {
    fn name(&self) -> &'static str {
        "runtime"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        self.0.stem_chunk(words)
    }
}

/// All four engines behind one backend (PR 3): the options word routes
/// each batch group to its engine, making algorithm + infix + trace
/// per-request serving parameters.
///
/// PR 4 puts the optional [`StemCache`] in front of kernel dispatch:
/// every trace-free request probes the shared cache first; only the
/// misses reach an engine, and their results are stored on the way out.
/// Trace requests bypass the cache entirely (a trace allocates and is
/// request-specific), so they always run the real pipeline and never
/// pollute the cache. Hits and misses are counted into the coordinator's
/// [`ServiceMetrics`] when one is attached.
pub struct RegistryBackend {
    registry: AnalyzerRegistry,
    cache: Option<Arc<StemCache>>,
    metrics: Option<Arc<ServiceMetrics>>,
}

impl RegistryBackend {
    pub fn new(roots: Arc<RootSet>) -> Self {
        Self::with_config(roots, StemmerConfig::default())
    }

    pub fn with_config(roots: Arc<RootSet>, cfg: StemmerConfig) -> Self {
        Self::with_cache(roots, cfg, None, None)
    }

    /// A registry backend fronted by `cache` (shared across workers),
    /// counting hits/misses into `metrics` when given.
    pub fn with_cache(
        roots: Arc<RootSet>,
        cfg: StemmerConfig,
        cache: Option<Arc<StemCache>>,
        metrics: Option<Arc<ServiceMetrics>>,
    ) -> Self {
        RegistryBackend {
            registry: AnalyzerRegistry::with_config(roots, cfg),
            cache,
            metrics,
        }
    }

    /// The cache-fronted dispatch core shared by both batch entry points.
    fn analyze_packed_cached(&self, words: &[PackedWord], opts: EngineOpts) -> Vec<Analysis> {
        let aopts = opts.to_options();
        let cache = match &self.cache {
            Some(c) if !aopts.want_trace => c,
            _ => return self.registry.analyze_batch_packed(words, &aopts),
        };
        let mut out: Vec<Option<Analysis>> = vec![None; words.len()];
        let mut miss_idx: Vec<usize> = Vec::new();
        let mut miss_words: Vec<PackedWord> = Vec::new();
        for (i, &w) in words.iter().enumerate() {
            match cache.lookup(w, opts) {
                Some(a) => out[i] = Some(a),
                None => {
                    miss_idx.push(i);
                    miss_words.push(w);
                }
            }
        }
        if let Some(m) = &self.metrics {
            let misses = miss_idx.len() as u64;
            // ord: Relaxed — statistics counter; no ordering required.
            m.cache_hits.fetch_add(words.len() as u64 - misses, Ordering::Relaxed);
            m.cache_misses.fetch_add(misses, Ordering::Relaxed); // ord: Relaxed — stats
        }
        if !miss_words.is_empty() {
            let computed = self.registry.analyze_batch_packed(&miss_words, &aopts);
            for (&i, a) in miss_idx.iter().zip(computed) {
                cache.insert(words[i], opts, &a);
                out[i] = Some(a);
            }
        }
        out.into_iter().map(|a| a.expect("every index hit or computed")).collect()
    }
}

impl StemBackend for RegistryBackend {
    fn name(&self) -> &'static str {
        "registry"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        use crate::analysis::Analyzer as _;
        Ok(self.registry.get(Algorithm::Linguistic).stem_batch(words))
    }

    fn analyze_batch_opts(
        &mut self,
        words: &[ArabicWord],
        opts: EngineOpts,
    ) -> Result<Vec<Analysis>> {
        // Without a cache there is nothing to key, so skip the
        // pack/unpack round-trip and dispatch the codepoint slice as-is
        // (pre-PR-4 behavior). With a cache, words are packed once here
        // — both to probe and because the kernels consume registers.
        if self.cache.is_none() {
            return Ok(self.registry.analyze_batch(words, &opts.to_options()));
        }
        let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();
        Ok(self.analyze_packed_cached(&packed, opts))
    }

    fn analyze_batch_packed(
        &mut self,
        words: &[PackedWord],
        opts: EngineOpts,
    ) -> Result<Vec<Analysis>> {
        Ok(self.analyze_packed_cached(words, opts))
    }
}

/// Factory for [`RegistryBackend`] workers (the `--backend registry`
/// serve default), cache-less.
pub fn registry_factory(roots: Arc<RootSet>, cfg: StemmerConfig) -> BackendFactory {
    registry_factory_cached(roots, cfg, None, None)
}

/// Factory for cache-fronted [`RegistryBackend`] workers: every worker
/// clones the same shared cache and metrics.
pub fn registry_factory_cached(
    roots: Arc<RootSet>,
    cfg: StemmerConfig,
    cache: Option<Arc<StemCache>>,
    metrics: Option<Arc<ServiceMetrics>>,
) -> BackendFactory {
    Box::new(move |_| {
        Ok(Box::new(RegistryBackend::with_cache(
            roots.clone(),
            cfg,
            cache.clone(),
            metrics.clone(),
        )))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::AnalyzeOptions;
    use crate::khoja::KhojaStemmer;
    use crate::light::{LightStemmer, VotingAnalyzer};
    use crate::roots::RootSet;
    use crate::stemmer::{MatchKind, Stemmer};

    fn sw_factory() -> BackendFactory {
        Box::new(|_id| {
            let roots = Arc::new(RootSet::builtin_mini());
            Ok(Box::new(SoftwareBackend(Stemmer::with_defaults(roots))))
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("سيلعبون")).unwrap();
        assert_eq!(r.root_word().to_string_ar(), "لعب");
        c.shutdown();
    }

    #[test]
    fn stream_preserves_order() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 4, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> =
            ["يدرس", "يلعب", "قال", "فتزحزحت", "ظظظ"].iter().map(|s| ArabicWord::encode(s)).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 5);
        assert_eq!(res[0].root_word().to_string_ar(), "درس");
        assert_eq!(res[1].root_word().to_string_ar(), "لعب");
        assert_eq!(res[2].root_word().to_string_ar(), "قول");
        assert_eq!(res[3].root_word().to_string_ar(), "زحزح");
        assert_eq!(res[4].kind, MatchKind::None);
        c.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 64, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = (0..512).map(|_| ArabicWord::encode("يدرسون")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 512);
        let snap = c.metrics().snapshot();
        assert_eq!(snap.words, 512);
        assert!(snap.batches < 512, "batching never aggregated: {}", snap.batches);
        assert!(snap.mean_batch_size > 1.0);
        c.shutdown();
    }

    #[test]
    fn multiple_workers() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 4, max_batch: 8, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = (0..256).map(|_| ArabicWord::encode("قال")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert!(res.iter().all(|r| r.kind == MatchKind::Restored));
        c.shutdown();
    }

    #[test]
    fn bulk_matches_per_word_and_preserves_order() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 16, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = ["يدرس", "قال", "ظظظ", "فتزحزحت", "سيلعبون"]
            .iter()
            .cycle()
            .take(100)
            .map(|s| ArabicWord::encode(s))
            .collect();
        let a = h.stem_bulk(&words).unwrap();
        let b = h.stem_stream(&words).unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    /// Streams far larger than the reply slab pipeline through the
    /// windowed core without deadlock, preserving order.
    #[test]
    fn stream_larger_than_reply_slab() {
        let cfg = CoordinatorConfig {
            workers: 2,
            max_batch: 16,
            queue_capacity: 32, // slab = 32 + 2*16 + 64 = 128 slots
            ..Default::default()
        };
        let slab_cap = cfg.reply_slots();
        let c = Coordinator::start(cfg, sw_factory());
        let h = c.handle();
        let vocab = ["يدرس", "قال", "ظظظ", "فتزحزحت", "سيلعبون"];
        let n = slab_cap * 8; // far past slab capacity
        let words: Vec<_> =
            vocab.iter().cycle().take(n).map(|s| ArabicWord::encode(s)).collect();
        let res = h.stem_bulk(&words).unwrap();
        assert_eq!(res.len(), n);
        // order check: every word's reply matches a direct stem
        let stemmer = Stemmer::with_defaults(Arc::new(RootSet::builtin_mini()));
        let expected = stemmer.stem_batch(&words);
        assert_eq!(res, expected);
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        c.shutdown();
        let err = h.submit(ArabicWord::encode("درس")).unwrap_err();
        assert_eq!(err.code, ErrorCode::Shutdown);
        assert_eq!(h.metrics().snapshot().rejected_shutdown, 1);
    }

    /// Partial-submit fix: a bulk call against a closed coordinator fails
    /// fast with a clean typed error — no hang, no stranded replies.
    #[test]
    fn bulk_after_shutdown_errors_without_hanging() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        c.shutdown();
        let words: Vec<_> = (0..64).map(|_| ArabicWord::encode("يدرس")).collect();
        let err = h.stem_bulk(&words).unwrap_err();
        assert_eq!(err.code, ErrorCode::Shutdown);
        assert!(format!("{err:#}").contains("closed"), "{err:#}");
        // The slab is fully recycled: a fresh coordinator-sized burst of
        // tickets is still acquirable.
        let pending_err = h.submit(ArabicWord::encode("قال"));
        assert!(pending_err.is_err());
    }

    /// Dropping a Pending un-waited abandons its ticket; the slot recycles
    /// once the worker fills it, so capacity is never leaked.
    #[test]
    fn dropped_pending_recycles_ticket() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 8, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        for _ in 0..10_000 {
            let p = h.submit(ArabicWord::encode("يدرس")).unwrap();
            drop(p); // abandon every reply
        }
        // If abandoned tickets leaked, the slab (~4096+ slots) would be
        // exhausted by now and this stream would deadlock.
        let words: Vec<_> = (0..128).map(|_| ArabicWord::encode("قال")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 128);
        c.shutdown();
    }

    #[test]
    fn failing_backend_reports_errors() {
        struct Failing;
        impl StemBackend for Failing {
            fn name(&self) -> &'static str {
                "failing"
            }
            fn stem_batch(&mut self, _w: &[ArabicWord]) -> Result<Vec<StemResult>> {
                anyhow::bail!("injected failure")
            }
        }
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, ..Default::default() },
            Box::new(|_| Ok(Box::new(Failing))),
        );
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("درس")).unwrap();
        assert_eq!(r, StemResult::NONE); // degraded reply, not a hang
        assert!(c.metrics().snapshot().errors >= 1);
        c.shutdown();
    }

    /// A panicking backend degrades to NONE replies instead of stranding
    /// parked waiters (slab tickets must always be filled).
    #[test]
    fn panicking_backend_degrades_instead_of_hanging() {
        struct Panicking;
        impl StemBackend for Panicking {
            fn name(&self) -> &'static str {
                "panicking"
            }
            fn stem_batch(&mut self, _w: &[ArabicWord]) -> Result<Vec<StemResult>> {
                panic!("injected panic")
            }
        }
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, ..Default::default() },
            Box::new(|_| Ok(Box::new(Panicking))),
        );
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("درس")).unwrap();
        assert_eq!(r, StemResult::NONE); // degraded reply, not a hang
        assert!(c.metrics().snapshot().errors >= 1);
        c.shutdown();
    }

    /// Backend init failure: the dead worker's reject loop fails requests
    /// with NONE immediately — a live serve process degrades loudly
    /// instead of parking every client forever.
    #[test]
    fn init_failure_rejects_requests_instead_of_hanging() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, ..Default::default() },
            Box::new(|_| anyhow::bail!("no backend for you")),
        );
        let h = c.handle();
        // Resolves without any shutdown: the reject loop answers it.
        let r = h.stem(ArabicWord::encode("درس")).unwrap();
        assert_eq!(r, StemResult::NONE);
        assert!(c.metrics().snapshot().errors >= 2); // init + rejected request
        c.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 32, ..Default::default() },
            sw_factory(),
        );
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let h = c.handle();
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        let r = h.stem(ArabicWord::encode("يدرس")).unwrap();
                        assert_eq!(r.root_word().to_string_ar(), "درس");
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(c.metrics().snapshot().requests, 400);
        c.shutdown();
    }

    // -- PR 3: per-request options through the registry ---------------------

    fn registry_coordinator(cfg: CoordinatorConfig) -> (Coordinator, Arc<RootSet>) {
        let roots = Arc::new(RootSet::builtin_mini());
        let c = Coordinator::start_registry(cfg, roots.clone(), StemmerConfig::default());
        (c, roots)
    }

    fn opts_for(algo: Algorithm) -> EngineOpts {
        EngineOpts::new(&AnalyzeOptions::with_algorithm(algo))
    }

    /// One coordinator answers all four algorithms concurrently, each
    /// bit-identical to a direct call into the engine.
    #[test]
    fn registry_serves_all_four_algorithms() {
        let (c, roots) = registry_coordinator(CoordinatorConfig {
            workers: 2,
            max_batch: 32,
            ..Default::default()
        });
        let h = c.handle();
        let vocab = ["يدرس", "قال", "دارس", "والدرس", "مدروس", "ظظظ"];
        let words: Vec<ArabicWord> = vocab.iter().map(|s| ArabicWord::encode(s)).collect();

        let lb = Stemmer::with_defaults(roots.clone());
        let kh = KhojaStemmer::new(roots.clone());
        let li = LightStemmer::new(roots.clone());
        let vo = VotingAnalyzer::new(roots.clone());
        let direct: [(Algorithm, Vec<StemResult>); 4] = [
            (Algorithm::Linguistic, words.iter().map(|w| lb.stem(w)).collect()),
            (Algorithm::Khoja, words.iter().map(|w| kh.stem(w)).collect()),
            (Algorithm::Light, words.iter().map(|w| li.stem(w)).collect()),
            (Algorithm::Voting, words.iter().map(|w| vo.stem(w)).collect()),
        ];

        // concurrent: one client thread per algorithm
        let threads: Vec<_> = direct
            .into_iter()
            .map(|(algo, expected)| {
                let h = c.handle();
                let words = words.clone();
                std::thread::spawn(move || {
                    for _ in 0..20 {
                        let got = h.analyze_bulk(&words, opts_for(algo)).unwrap();
                        let got: Vec<StemResult> = got.into_iter().map(|a| a.result).collect();
                        assert_eq!(got, expected, "{algo}");
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        c.shutdown();
    }

    /// A single mixed-options stream (interleaved algorithms + infix
    /// overrides) routes every word to the right engine.
    #[test]
    fn mixed_options_within_one_batch() {
        let (c, roots) = registry_coordinator(CoordinatorConfig {
            workers: 1,
            max_batch: 64,
            ..Default::default()
        });
        let h = c.handle();
        let w = ArabicWord::encode("قال"); // the discriminating word
        let kh = KhojaStemmer::new(roots.clone());
        let lb = Stemmer::with_defaults(roots);

        let infix_off = EngineOpts::new(&AnalyzeOptions {
            infix: Some(false),
            ..Default::default()
        });
        // Interleave submissions so one popped batch carries several
        // option groups.
        let pendings: Vec<(Pending, StemResult)> = (0..30)
            .map(|i| match i % 3 {
                0 => (h.submit_opts(w, EngineOpts::default()).unwrap(), lb.stem(&w)),
                1 => (h.submit_opts(w, opts_for(Algorithm::Khoja)).unwrap(), kh.stem(&w)),
                _ => (h.submit_opts(w, infix_off).unwrap(), StemResult::NONE),
            })
            .collect();
        for (p, expected) in pendings {
            assert_eq!(p.wait().result, expected);
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.requests, 30);
        assert_eq!(snap.words, 30);
        c.shutdown();
    }

    /// Traces ride through the coordinator when requested.
    #[test]
    fn trace_flows_through_coordinator() {
        let (c, _) = registry_coordinator(CoordinatorConfig::default());
        let h = c.handle();
        let opts = EngineOpts::new(&AnalyzeOptions { want_trace: true, ..Default::default() });
        let a = h.analyze(ArabicWord::encode("سيلعبون"), opts).unwrap();
        let trace = a.trace.expect("trace requested");
        assert_eq!(trace.stages.len(), 5);
        // and absent when not requested
        let a = h.analyze(ArabicWord::encode("سيلعبون"), EngineOpts::default()).unwrap();
        assert!(a.trace.is_none());
        c.shutdown();
    }

    // -- PR 4: packed requests + the memoizing stem cache -------------------

    /// Packed bulk entry points agree with the ArabicWord ones.
    #[test]
    fn packed_bulk_matches_array_bulk() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 16, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<ArabicWord> = ["يدرس", "قال", "ظظظ", "فتزحزحت", "سيلعبون"]
            .iter()
            .cycle()
            .take(100)
            .map(|s| ArabicWord::encode(s))
            .collect();
        let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();
        assert_eq!(h.stem_bulk_packed(&packed).unwrap(), h.stem_bulk(&words).unwrap());
        let r = h.submit_packed(packed[0]).unwrap().wait();
        assert_eq!(r.result.root_word().to_string_ar(), "درس");
        c.shutdown();
    }

    /// The cache's hit path is bit-identical to the miss path across
    /// mixed options: the same mixed-algorithm word stream run cold
    /// (all misses) and warm (hits) produces identical results, and the
    /// hit/miss counters move as expected.
    #[test]
    fn cache_hit_path_identical_to_miss_path() {
        let roots = Arc::new(RootSet::builtin_mini());
        let c = Coordinator::start_registry_cached(
            CoordinatorConfig { workers: 2, max_batch: 32, ..Default::default() },
            roots,
            StemmerConfig::default(),
            4096,
        );
        let h = c.handle();
        let words: Vec<ArabicWord> = ["يدرس", "قال", "دارس", "والدرس", "مدروس", "ظظظ"]
            .iter()
            .cycle()
            .take(120)
            .map(|s| ArabicWord::encode(s))
            .collect();
        let mut cold: Vec<Vec<Analysis>> = Vec::new();
        for algo in Algorithm::ALL {
            cold.push(h.analyze_bulk(&words, opts_for(algo)).unwrap());
        }
        let after_cold = h.metrics().snapshot();
        assert!(after_cold.cache_misses > 0, "cold pass must miss");
        for (algo, cold_pass) in Algorithm::ALL.iter().zip(&cold) {
            let warm = h.analyze_bulk(&words, opts_for(*algo)).unwrap();
            assert_eq!(&warm, cold_pass, "{algo}: warm != cold");
        }
        let after_warm = h.metrics().snapshot();
        // The cache is direct-mapped, so a few of the 24 (word, opts) keys
        // may collide and evict each other across passes — require the
        // warm pass to be dominated by hits, not to hit perfectly.
        assert!(
            after_warm.cache_hits >= after_cold.cache_hits + 2 * words.len() as u64,
            "warm pass must mostly hit: {after_warm:?}"
        );
        // infix override is part of the key: no cross-contamination
        let infix_off = EngineOpts::new(&AnalyzeOptions {
            infix: Some(false),
            ..Default::default()
        });
        let w = ArabicWord::encode("قال");
        assert_eq!(h.analyze(w, EngineOpts::default()).unwrap().result.kind, MatchKind::Restored);
        assert_eq!(h.analyze(w, infix_off).unwrap().result.kind, MatchKind::None);
        assert_eq!(h.analyze(w, EngineOpts::default()).unwrap().result.kind, MatchKind::Restored);
        c.shutdown();
    }

    /// Trace requests bypass the cache: they always carry a trace (even
    /// when the same word is already cached trace-less) and never seed
    /// trace-less entries with wrong shapes.
    #[test]
    fn trace_requests_bypass_cache() {
        let roots = Arc::new(RootSet::builtin_mini());
        let c = Coordinator::start_registry_cached(
            CoordinatorConfig::default(),
            roots,
            StemmerConfig::default(),
            1024,
        );
        let h = c.handle();
        let w = ArabicWord::encode("سيلعبون");
        let trace_opts =
            EngineOpts::new(&AnalyzeOptions { want_trace: true, ..Default::default() });
        // warm the trace-less entry first
        assert!(h.analyze(w, EngineOpts::default()).unwrap().trace.is_none());
        for _ in 0..3 {
            let a = h.analyze(w, trace_opts).unwrap();
            let trace = a.trace.expect("trace requested must always trace");
            assert_eq!(trace.stages.len(), 5);
        }
        // and the trace-less path still returns no trace afterwards
        assert!(h.analyze(w, EngineOpts::default()).unwrap().trace.is_none());
        let snap = c.metrics().snapshot();
        assert_eq!(snap.errors, 0);
        c.shutdown();
    }

    /// `cache_slots = 0` disables the cache: serving still works and the
    /// counters stay at zero.
    #[test]
    fn cache_disabled_serves_identically() {
        let roots = Arc::new(RootSet::builtin_mini());
        let c = Coordinator::start_registry_cached(
            CoordinatorConfig::default(),
            roots.clone(),
            StemmerConfig::default(),
            0,
        );
        let h = c.handle();
        let words: Vec<ArabicWord> =
            ["يدرس", "قال", "ظظظ"].iter().map(|s| ArabicWord::encode(s)).collect();
        let direct = Stemmer::with_defaults(roots).stem_batch(&words);
        for _ in 0..2 {
            let got = h.stem_bulk(&words).unwrap();
            assert_eq!(got, direct);
        }
        let snap = c.metrics().snapshot();
        assert_eq!(snap.cache_hits + snap.cache_misses, 0, "no cache counters when disabled");
        c.shutdown();
    }

    /// A queue that stays full past the submission deadline sheds typed
    /// QUEUE_FULL instead of blocking forever; accepted replies drain.
    #[test]
    fn queue_full_deadline_rejects_typed() {
        struct Slow;
        impl StemBackend for Slow {
            fn name(&self) -> &'static str {
                "slow"
            }
            fn stem_batch(&mut self, w: &[ArabicWord]) -> Result<Vec<StemResult>> {
                std::thread::sleep(Duration::from_millis(600));
                Ok(vec![StemResult::NONE; w.len()])
            }
        }
        let c = Coordinator::start(
            CoordinatorConfig {
                workers: 1,
                max_batch: 1,
                queue_capacity: 1,
                max_wait: Duration::from_millis(1),
            },
            Box::new(|_| Ok(Box::new(Slow))),
        );
        let h = c.handle();
        let words: Vec<_> = (0..4).map(|_| ArabicWord::encode("يدرس")).collect();
        let err = h
            .analyze_bulk_deadline(&words, EngineOpts::default(), Duration::from_millis(50))
            .unwrap_err();
        assert_eq!(err.code, ErrorCode::QueueFull, "{err}");
        assert!(h.metrics().snapshot().rejected_queue_full >= 1);
        c.shutdown();
    }
}
