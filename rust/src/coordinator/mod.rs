//! L3 coordinator: dynamic batcher + worker pool + backpressure — the
//! serving organization around the stemmer backends.
//!
//! The paper's pipelined processor overlaps five datapath stages so a new
//! word enters every clock. The serving analog: requests stream into a
//! bounded queue (backpressure), a batcher groups whatever is waiting (up
//! to `max_batch`, with a `max_wait` deadline — the classic dynamic
//! batching policy), and worker threads run the batch on a pluggable
//! [`StemBackend`]: the pure-rust software stemmer, either FPGA-simulator
//! processor, or the PJRT engine executing the AOT JAX artifact.
//!
//! Backends are constructed *on* their worker thread via a factory, which
//! is what lets the `Rc`-based PJRT engine participate without being
//! `Send`.

use crate::chars::ArabicWord;
use crate::exec::{BoundedQueue, QueueError, WorkerPool};
use crate::metrics::ServiceMetrics;
use crate::stemmer::StemResult;
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// A batch-oriented root-extraction backend.
pub trait StemBackend {
    fn name(&self) -> &'static str;
    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>>;
}

/// Constructs a backend on the worker thread (worker id passed in).
pub type BackendFactory = Box<dyn Fn(usize) -> Result<Box<dyn StemBackend>> + Send + Sync>;

/// Where a finished result goes.
enum ReplyTo {
    /// One dedicated channel per request (interactive path).
    Single(mpsc::Sender<StemResult>),
    /// Shared indexed channel (bulk path — one allocation per *stream*
    /// instead of per word; the §Perf L3 fix, see EXPERIMENTS.md).
    Bulk(mpsc::Sender<(u32, StemResult)>, u32),
}

/// One queued request.
struct Request {
    word: ArabicWord,
    submitted: Instant,
    reply: ReplyTo,
}

/// Batching/queueing policy.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Maximum words per dispatched batch.
    pub max_batch: usize,
    /// How long the batcher waits for the first word of a batch.
    pub max_wait: Duration,
    /// Bounded request-queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// Number of backend workers.
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
            workers: 1,
        }
    }
}

/// The running coordinator.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Request>>,
    pool: Option<WorkerPool>,
    metrics: Arc<ServiceMetrics>,
}

impl Coordinator {
    /// Start workers, each owning a backend built by `factory`.
    pub fn start(cfg: CoordinatorConfig, factory: BackendFactory) -> Self {
        let queue: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.queue_capacity);
        let metrics = Arc::new(ServiceMetrics::new());
        let q = queue.clone();
        let m = metrics.clone();
        let factory = Arc::new(factory);
        let pool = WorkerPool::spawn(cfg.workers, "stem-worker", move |id, _sd| {
            let mut backend = match factory(id) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("worker {id}: backend init failed: {e:#}");
                    m.errors.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            };
            let mut words = Vec::with_capacity(cfg.max_batch);
            loop {
                let batch = match q.pop_batch(cfg.max_batch, cfg.max_wait) {
                    Ok(b) => b,
                    Err(QueueError::Timeout) => continue,
                    Err(_) => break, // closed and drained
                };
                words.clear();
                words.extend(batch.iter().map(|r| r.word));
                match backend.stem_batch(&words) {
                    Ok(results) => {
                        m.record_batch(words.len() as u64);
                        for (req, res) in batch.into_iter().zip(results) {
                            m.record_latency(req.submitted.elapsed());
                            match req.reply {
                                ReplyTo::Single(tx) => drop(tx.send(res)),
                                ReplyTo::Bulk(tx, idx) => drop(tx.send((idx, res))),
                            }
                        }
                    }
                    Err(e) => {
                        eprintln!("worker {id}: batch failed: {e:#}");
                        m.errors.fetch_add(1, Ordering::Relaxed);
                        for req in batch {
                            match req.reply {
                                ReplyTo::Single(tx) => drop(tx.send(StemResult::NONE)),
                                ReplyTo::Bulk(tx, idx) => drop(tx.send((idx, StemResult::NONE))),
                            }
                        }
                    }
                }
            }
        });
        Coordinator { queue, pool: Some(pool), metrics }
    }

    pub fn handle(&self) -> Handle {
        Handle { queue: self.queue.clone() }
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Graceful shutdown: stop intake, drain, join workers.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
    }
}

/// Cheap, cloneable client handle.
#[derive(Clone)]
pub struct Handle {
    queue: Arc<BoundedQueue<Request>>,
}

/// A pending reply.
pub struct Pending {
    rx: mpsc::Receiver<StemResult>,
}

impl Pending {
    pub fn wait(self) -> Result<StemResult> {
        Ok(self.rx.recv()?)
    }

    pub fn wait_timeout(self, d: Duration) -> Result<StemResult> {
        Ok(self.rx.recv_timeout(d)?)
    }
}

impl Handle {
    /// Submit one word; blocks only if the queue is full (backpressure).
    pub fn submit(&self, word: ArabicWord) -> Result<Pending> {
        let (tx, rx) = mpsc::channel();
        self.queue
            .push(Request { word, submitted: Instant::now(), reply: ReplyTo::Single(tx) })
            .map_err(|e| anyhow::anyhow!("coordinator closed: {e:?}"))?;
        Ok(Pending { rx })
    }

    /// Bulk submission: one shared reply channel for the whole slice
    /// (order restored by index). ~3× less allocation/synchronization than
    /// per-word [`Handle::submit`] on large streams.
    pub fn stem_bulk(&self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        let (tx, rx) = mpsc::channel();
        let now = Instant::now();
        for (i, &word) in words.iter().enumerate() {
            self.queue
                .push(Request {
                    word,
                    submitted: now,
                    reply: ReplyTo::Bulk(tx.clone(), i as u32),
                })
                .map_err(|e| anyhow::anyhow!("coordinator closed: {e:?}"))?;
        }
        drop(tx);
        let mut out = vec![StemResult::NONE; words.len()];
        let mut got = 0usize;
        while got < words.len() {
            let (idx, res) = rx.recv()?;
            out[idx as usize] = res;
            got += 1;
        }
        Ok(out)
    }

    /// Synchronous single-word convenience.
    pub fn stem(&self, word: ArabicWord) -> Result<StemResult> {
        self.submit(word)?.wait()
    }

    /// Pipeline a whole slice through the coordinator, preserving order.
    /// Submissions overlap execution — the serving analog of the paper's
    /// pipelined processor keeping every stage busy.
    pub fn stem_stream(&self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        let mut pending = Vec::with_capacity(words.len());
        for &w in words {
            pending.push(self.submit(w)?);
        }
        pending.into_iter().map(|p| p.wait()).collect()
    }
}

// ---------------------------------------------------------------------------
// Backend implementations
// ---------------------------------------------------------------------------

/// The software stemmer as a backend — the default. Batches go through
/// the SoA fused kernel (`Stemmer::stem_batch`): dense-index encoding,
/// AffixProfile candidate checks, direct-addressed dictionary bitsets.
pub struct SoftwareBackend(pub crate::stemmer::Stemmer);

impl StemBackend for SoftwareBackend {
    fn name(&self) -> &'static str {
        "software"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.0.stem_batch(words))
    }
}

/// The software stemmer with intra-batch parallelism: large batches fan
/// out across an internal `exec::WorkerPool`
/// (`Stemmer::stem_batch_parallel`). Useful when the coordinator runs few
/// workers but receives large bulk batches.
pub struct ParallelSoftwareBackend {
    pub stemmer: crate::stemmer::Stemmer,
    pub threads: usize,
}

impl StemBackend for ParallelSoftwareBackend {
    fn name(&self) -> &'static str {
        "software-par"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.stemmer.stem_batch_parallel(words, self.threads))
    }
}

/// Either FPGA-simulator processor as a backend.
pub struct HwBackend<P: crate::hw::Processor>(pub P);

impl<P: crate::hw::Processor> StemBackend for HwBackend<P> {
    fn name(&self) -> &'static str {
        "hw-sim"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.0.run(words).0)
    }
}

/// The PJRT engine as a backend (constructed on the worker thread).
pub struct XlaBackend(pub crate::runtime::Engine);

impl StemBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        self.0.stem_chunk(words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roots::RootSet;
    use crate::stemmer::{MatchKind, Stemmer};

    fn sw_factory() -> BackendFactory {
        Box::new(|_id| {
            let roots = Arc::new(RootSet::builtin_mini());
            Ok(Box::new(SoftwareBackend(Stemmer::with_defaults(roots))))
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("سيلعبون")).unwrap();
        assert_eq!(r.root_word().to_string_ar(), "لعب");
        c.shutdown();
    }

    #[test]
    fn stream_preserves_order() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 4, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> =
            ["يدرس", "يلعب", "قال", "فتزحزحت", "ظظظ"].iter().map(|s| ArabicWord::encode(s)).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 5);
        assert_eq!(res[0].root_word().to_string_ar(), "درس");
        assert_eq!(res[1].root_word().to_string_ar(), "لعب");
        assert_eq!(res[2].root_word().to_string_ar(), "قول");
        assert_eq!(res[3].root_word().to_string_ar(), "زحزح");
        assert_eq!(res[4].kind, MatchKind::None);
        c.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 64, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = (0..512).map(|_| ArabicWord::encode("يدرسون")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 512);
        let snap = c.metrics().snapshot();
        assert_eq!(snap.words, 512);
        assert!(snap.batches < 512, "batching never aggregated: {}", snap.batches);
        assert!(snap.mean_batch_size > 1.0);
        c.shutdown();
    }

    #[test]
    fn multiple_workers() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 4, max_batch: 8, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = (0..256).map(|_| ArabicWord::encode("قال")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert!(res.iter().all(|r| r.kind == MatchKind::Restored));
        c.shutdown();
    }

    #[test]
    fn bulk_matches_per_word_and_preserves_order() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 16, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = ["يدرس", "قال", "ظظظ", "فتزحزحت", "سيلعبون"]
            .iter()
            .cycle()
            .take(100)
            .map(|s| ArabicWord::encode(s))
            .collect();
        let a = h.stem_bulk(&words).unwrap();
        let b = h.stem_stream(&words).unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        c.shutdown();
        assert!(h.submit(ArabicWord::encode("درس")).is_err());
    }

    #[test]
    fn failing_backend_reports_errors() {
        struct Failing;
        impl StemBackend for Failing {
            fn name(&self) -> &'static str {
                "failing"
            }
            fn stem_batch(&mut self, _w: &[ArabicWord]) -> Result<Vec<StemResult>> {
                anyhow::bail!("injected failure")
            }
        }
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, ..Default::default() },
            Box::new(|_| Ok(Box::new(Failing))),
        );
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("درس")).unwrap();
        assert_eq!(r, StemResult::NONE); // degraded reply, not a hang
        assert!(c.metrics().snapshot().errors >= 1);
        c.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 32, ..Default::default() },
            sw_factory(),
        );
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let h = c.handle();
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        let r = h.stem(ArabicWord::encode("يدرس")).unwrap();
                        assert_eq!(r.root_word().to_string_ar(), "درس");
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(c.metrics().snapshot().requests, 400);
        c.shutdown();
    }
}
