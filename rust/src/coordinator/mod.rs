//! L3 coordinator: dynamic batcher + worker pool + backpressure — the
//! serving organization around the stemmer backends.
//!
//! The paper's pipelined processor overlaps five datapath stages so a new
//! word enters every clock; its headline 5571× speedup comes from the
//! *organization around* the datapath as much as the datapath itself. The
//! serving analog keeps every stage busy with zero per-word allocation:
//!
//! * **Intake** — requests stream into a bounded [`BoundedQueue`]
//!   (backpressure: producers stall when the queue fills, exactly like the
//!   paper's pipeline stalling its front end).
//! * **Batching** — a dynamic batcher groups whatever is waiting (up to
//!   `max_batch`, with a `max_wait` deadline) and hands it to a worker
//!   running a pluggable [`StemBackend`]: the pure-rust software stemmer,
//!   either FPGA-simulator processor, or the PJRT engine executing the
//!   AOT JAX artifact.
//! * **Reply routing** — instead of one `mpsc::channel()` allocation per
//!   word (PR 1's hot-path residue), every request carries a `ticket`
//!   into a shared [`exec::ReplySlab`]: a fixed-capacity, index-addressed
//!   slab of reusable reply slots with park/unpark wakeups. Workers
//!   `fill(ticket, result)`; submitters `wait(ticket)`. The steady-state
//!   submit → stem → reply cycle allocates nothing.
//!
//! [`Handle::stem_bulk`] / [`Handle::stem_stream`] share a *windowed*
//! submit/collect core: up to half the slab may be in flight per call, and
//! when the slab runs dry the submitter reaps its own oldest reply before
//! acquiring more — so arbitrarily large streams pipeline through the
//! fixed slab without deadlock, preserving submission order throughout.
//!
//! Backends are constructed *on* their worker thread via a factory, which
//! is what lets the `Rc`-based PJRT engine participate without being
//! `Send`.

use crate::chars::ArabicWord;
use crate::exec::{BoundedQueue, QueueError, ReplySlab, WorkerPool};
use crate::metrics::ServiceMetrics;
use crate::stemmer::StemResult;
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A batch-oriented root-extraction backend.
pub trait StemBackend {
    fn name(&self) -> &'static str;
    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>>;
}

/// Constructs a backend on the worker thread (worker id passed in).
pub type BackendFactory = Box<dyn Fn(usize) -> Result<Box<dyn StemBackend>> + Send + Sync>;

/// One queued request: the word plus the reply-slab ticket its result is
/// routed to. Plain data, no heap, no per-request channel.
struct Request {
    word: ArabicWord,
    submitted: Instant,
    ticket: u32,
}

/// Batching/queueing policy.
#[derive(Clone, Copy, Debug)]
pub struct CoordinatorConfig {
    /// Maximum words per dispatched batch.
    pub max_batch: usize,
    /// How long the batcher waits for the first word of a batch.
    pub max_wait: Duration,
    /// Bounded request-queue capacity (backpressure threshold).
    pub queue_capacity: usize,
    /// Number of backend workers.
    pub workers: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_batch: 256,
            max_wait: Duration::from_millis(2),
            queue_capacity: 4096,
            workers: 1,
        }
    }
}

impl CoordinatorConfig {
    /// Reply-slab capacity: everything that can be in flight at once —
    /// the full request queue plus one max-size batch per worker — with
    /// headroom for submitters between `acquire` and `push`.
    fn reply_slots(&self) -> usize {
        self.queue_capacity + self.workers * self.max_batch + 64
    }
}

/// The running coordinator.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Request>>,
    slab: Arc<ReplySlab<StemResult>>,
    pool: Option<WorkerPool>,
    metrics: Arc<ServiceMetrics>,
}

impl Coordinator {
    /// Start workers, each owning a backend built by `factory`.
    pub fn start(cfg: CoordinatorConfig, factory: BackendFactory) -> Self {
        let queue: Arc<BoundedQueue<Request>> = BoundedQueue::new(cfg.queue_capacity);
        let slab: Arc<ReplySlab<StemResult>> = ReplySlab::new(cfg.reply_slots());
        let metrics = Arc::new(ServiceMetrics::new());
        let q = queue.clone();
        let s = slab.clone();
        let m = metrics.clone();
        let factory = Arc::new(factory);
        let failed_inits = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let pool = WorkerPool::spawn(cfg.workers, "stem-worker", move |id, _sd| {
            let mut backend = match factory(id) {
                Ok(b) => b,
                Err(e) => {
                    eprintln!("worker {id}: backend init failed: {e:#}");
                    m.errors.fetch_add(1, Ordering::Relaxed);
                    // If EVERY worker failed init, nobody will ever pop the
                    // queue — the last worker to fail runs a reject loop so
                    // a live serve process degrades loudly (NONE replies)
                    // instead of parking every client forever. With any
                    // healthy sibling, just exit and let it serve 100%.
                    if failed_inits.fetch_add(1, Ordering::SeqCst) + 1 == cfg.workers {
                        while let Ok(req) = q.pop() {
                            m.errors.fetch_add(1, Ordering::Relaxed);
                            s.fill(req.ticket, StemResult::NONE);
                        }
                    }
                    return;
                }
            };
            let mut words = Vec::with_capacity(cfg.max_batch);
            loop {
                let batch = match q.pop_batch(cfg.max_batch, cfg.max_wait) {
                    Ok(b) => b,
                    Err(QueueError::Timeout) => continue,
                    Err(_) => break, // closed and drained
                };
                words.clear();
                words.extend(batch.iter().map(|r| r.word));
                // Every popped ticket MUST be filled, whatever the backend
                // does — a panic or a short result vector would otherwise
                // leave waiters parked forever (the old mpsc design woke
                // them via dropped Senders; the slab has no such tripwire).
                let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    backend.stem_batch(&words)
                }));
                let results = match outcome {
                    Ok(Ok(results)) if results.len() == words.len() => Some(results),
                    Ok(Ok(results)) => {
                        eprintln!(
                            "worker {id}: backend returned {} results for {} words",
                            results.len(),
                            words.len()
                        );
                        None
                    }
                    Ok(Err(e)) => {
                        eprintln!("worker {id}: batch failed: {e:#}");
                        None
                    }
                    Err(_) => {
                        eprintln!("worker {id}: backend panicked; failing the batch");
                        None
                    }
                };
                match results {
                    Some(results) => {
                        m.record_batch(words.len() as u64);
                        for (req, res) in batch.into_iter().zip(results) {
                            m.record_latency(req.submitted.elapsed());
                            s.fill(req.ticket, res);
                        }
                    }
                    None => {
                        m.errors.fetch_add(1, Ordering::Relaxed);
                        for req in batch {
                            s.fill(req.ticket, StemResult::NONE);
                        }
                    }
                }
            }
        });
        Coordinator { queue, slab, pool: Some(pool), metrics }
    }

    pub fn handle(&self) -> Handle {
        Handle {
            queue: self.queue.clone(),
            slab: self.slab.clone(),
            metrics: self.metrics.clone(),
        }
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Graceful shutdown: stop intake, drain, join workers.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.queue.close();
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
        // If a worker died before draining (e.g. backend init failure),
        // requests may be stranded in the queue with waiters parked on
        // their tickets. Fail them instead of leaving replies in flight.
        while let Ok(req) = self.queue.pop_timeout(Duration::ZERO) {
            self.metrics.errors.fetch_add(1, Ordering::Relaxed);
            self.slab.fill(req.ticket, StemResult::NONE);
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Cheap, cloneable client handle.
#[derive(Clone)]
pub struct Handle {
    queue: Arc<BoundedQueue<Request>>,
    slab: Arc<ReplySlab<StemResult>>,
    metrics: Arc<ServiceMetrics>,
}

/// A pending reply: a live reply-slab ticket. Dropping it un-waited
/// abandons the ticket (the slot recycles when the worker fills it).
pub struct Pending {
    slab: Arc<ReplySlab<StemResult>>,
    ticket: u32,
    done: bool,
}

impl Pending {
    pub fn wait(mut self) -> Result<StemResult> {
        self.done = true;
        Ok(self.slab.wait(self.ticket))
    }

    pub fn wait_timeout(mut self, d: Duration) -> Result<StemResult> {
        self.done = true;
        self.slab
            .wait_timeout(self.ticket, d)
            .map_err(|e| anyhow!("reply timed out: {e:?}"))
    }
}

impl Drop for Pending {
    fn drop(&mut self) {
        if !self.done {
            self.slab.abandon(self.ticket);
        }
    }
}

/// How long a failed bulk submission waits for already-accepted replies
/// before abandoning them (shutdown races resolve in microseconds; this
/// is a hang backstop, not a latency target).
const DRAIN_GRACE: Duration = Duration::from_secs(5);

impl Handle {
    /// Acquire a reply ticket, counting slab exhaustion as saturation.
    fn acquire_ticket(&self) -> u32 {
        match self.slab.try_acquire() {
            Some(t) => t,
            None => {
                self.metrics.slab_waits.fetch_add(1, Ordering::Relaxed);
                self.slab.acquire()
            }
        }
    }

    /// Enqueue a request, counting a full queue as saturation.
    fn enqueue(&self, word: ArabicWord, submitted: Instant, ticket: u32) -> Result<(), QueueError> {
        match self.queue.try_push(Request { word, submitted, ticket }) {
            Ok(()) => Ok(()),
            Err((req, QueueError::WouldBlock)) => {
                self.metrics.queue_full_events.fetch_add(1, Ordering::Relaxed);
                self.queue.push(req)
            }
            Err((_, e)) => Err(e),
        }
    }

    /// Service metrics shared with the coordinator that issued this handle.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.metrics
    }

    /// Submit one word; blocks only if the queue or reply slab is full
    /// (backpressure). Allocation-free on the steady-state path.
    pub fn submit(&self, word: ArabicWord) -> Result<Pending> {
        let ticket = self.acquire_ticket();
        match self.enqueue(word, Instant::now(), ticket) {
            Ok(()) => Ok(Pending { slab: self.slab.clone(), ticket, done: false }),
            Err(e) => {
                // The request never reached a worker; recycle directly.
                self.slab.release_unused(ticket);
                Err(anyhow!("coordinator closed: {e:?}"))
            }
        }
    }

    /// Synchronous single-word convenience.
    pub fn stem(&self, word: ArabicWord) -> Result<StemResult> {
        self.submit(word)?.wait()
    }

    /// Bulk submission through the windowed core: submissions overlap
    /// execution and replies route through reusable slab slots — zero
    /// allocation per word, order preserved.
    pub fn stem_bulk(&self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        self.stem_windowed(words)
    }

    /// Pipeline a whole slice through the coordinator, preserving order.
    /// Same windowed core as [`Handle::stem_bulk`] — the serving analog of
    /// the paper's pipelined processor keeping every stage busy.
    pub fn stem_stream(&self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        self.stem_windowed(words)
    }

    /// Windowed submit/collect: keep up to `window` tickets in flight;
    /// when the slab runs dry, reap our own oldest reply (guaranteed to be
    /// filled eventually, since it was accepted by the queue) instead of
    /// deadlocking on capacity we ourselves are holding.
    fn stem_windowed(&self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        let window = (self.slab.capacity() / 2).max(1);
        let submitted = Instant::now();
        let mut out: Vec<StemResult> = Vec::with_capacity(words.len());
        let mut inflight: VecDeque<u32> = VecDeque::with_capacity(window.min(words.len()));
        for &word in words {
            if inflight.len() >= window {
                let t = inflight.pop_front().expect("window non-empty");
                out.push(self.slab.wait(t));
            }
            let ticket = loop {
                if let Some(t) = self.slab.try_acquire() {
                    break t;
                }
                match inflight.pop_front() {
                    // Slab exhausted but we hold in-flight tickets: reap
                    // the oldest to free a slot.
                    Some(t) => out.push(self.slab.wait(t)),
                    // Nothing of ours in flight: block on other clients.
                    None => {
                        self.metrics.slab_waits.fetch_add(1, Ordering::Relaxed);
                        break self.slab.acquire();
                    }
                }
            };
            if let Err(e) = self.enqueue(word, submitted, ticket) {
                self.slab.release_unused(ticket);
                // Partial-submit fix: the queue closed mid-stream. Drain
                // every already-accepted reply (workers drain the queue
                // even after close) so nothing is left in flight, then
                // report how far we got.
                let accepted = out.len() + inflight.len();
                for t in inflight.drain(..) {
                    if let Ok(r) = self.slab.wait_timeout(t, DRAIN_GRACE) {
                        out.push(r);
                    }
                }
                bail!(
                    "coordinator closed mid-stream ({e:?}): {}/{} words accepted, \
                     {} replies drained",
                    accepted,
                    words.len(),
                    out.len()
                );
            }
            inflight.push_back(ticket);
        }
        for t in inflight.drain(..) {
            out.push(self.slab.wait(t));
        }
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Backend implementations
// ---------------------------------------------------------------------------

/// The software stemmer as a backend — the default. Batches go through
/// the SoA fused kernel (`Stemmer::stem_batch`): dense-index encoding,
/// AffixProfile candidate checks, direct-addressed dictionary bitsets.
pub struct SoftwareBackend(pub crate::stemmer::Stemmer);

impl StemBackend for SoftwareBackend {
    fn name(&self) -> &'static str {
        "software"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.0.stem_batch(words))
    }
}

/// The software stemmer with intra-batch parallelism: large batches fan
/// out across an internal `exec::WorkerPool`
/// (`Stemmer::stem_batch_parallel`). Useful when the coordinator runs few
/// workers but receives large bulk batches.
pub struct ParallelSoftwareBackend {
    pub stemmer: crate::stemmer::Stemmer,
    pub threads: usize,
}

impl StemBackend for ParallelSoftwareBackend {
    fn name(&self) -> &'static str {
        "software-par"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.stemmer.stem_batch_parallel(words, self.threads))
    }
}

/// Either FPGA-simulator processor as a backend.
pub struct HwBackend<P: crate::hw::Processor>(pub P);

impl<P: crate::hw::Processor> StemBackend for HwBackend<P> {
    fn name(&self) -> &'static str {
        "hw-sim"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        Ok(self.0.run(words).0)
    }
}

/// The PJRT engine as a backend (constructed on the worker thread).
pub struct XlaBackend(pub crate::runtime::Engine);

impl StemBackend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn stem_batch(&mut self, words: &[ArabicWord]) -> Result<Vec<StemResult>> {
        self.0.stem_chunk(words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roots::RootSet;
    use crate::stemmer::{MatchKind, Stemmer};

    fn sw_factory() -> BackendFactory {
        Box::new(|_id| {
            let roots = Arc::new(RootSet::builtin_mini());
            Ok(Box::new(SoftwareBackend(Stemmer::with_defaults(roots))))
        })
    }

    #[test]
    fn single_request_roundtrip() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("سيلعبون")).unwrap();
        assert_eq!(r.root_word().to_string_ar(), "لعب");
        c.shutdown();
    }

    #[test]
    fn stream_preserves_order() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 4, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> =
            ["يدرس", "يلعب", "قال", "فتزحزحت", "ظظظ"].iter().map(|s| ArabicWord::encode(s)).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 5);
        assert_eq!(res[0].root_word().to_string_ar(), "درس");
        assert_eq!(res[1].root_word().to_string_ar(), "لعب");
        assert_eq!(res[2].root_word().to_string_ar(), "قول");
        assert_eq!(res[3].root_word().to_string_ar(), "زحزح");
        assert_eq!(res[4].kind, MatchKind::None);
        c.shutdown();
    }

    #[test]
    fn batches_form_under_load() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 64, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = (0..512).map(|_| ArabicWord::encode("يدرسون")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 512);
        let snap = c.metrics().snapshot();
        assert_eq!(snap.words, 512);
        assert!(snap.batches < 512, "batching never aggregated: {}", snap.batches);
        assert!(snap.mean_batch_size > 1.0);
        c.shutdown();
    }

    #[test]
    fn multiple_workers() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 4, max_batch: 8, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = (0..256).map(|_| ArabicWord::encode("قال")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert!(res.iter().all(|r| r.kind == MatchKind::Restored));
        c.shutdown();
    }

    #[test]
    fn bulk_matches_per_word_and_preserves_order() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 16, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        let words: Vec<_> = ["يدرس", "قال", "ظظظ", "فتزحزحت", "سيلعبون"]
            .iter()
            .cycle()
            .take(100)
            .map(|s| ArabicWord::encode(s))
            .collect();
        let a = h.stem_bulk(&words).unwrap();
        let b = h.stem_stream(&words).unwrap();
        assert_eq!(a, b);
        c.shutdown();
    }

    /// Streams far larger than the reply slab pipeline through the
    /// windowed core without deadlock, preserving order.
    #[test]
    fn stream_larger_than_reply_slab() {
        let cfg = CoordinatorConfig {
            workers: 2,
            max_batch: 16,
            queue_capacity: 32, // slab = 32 + 2*16 + 64 = 128 slots
            ..Default::default()
        };
        let slab_cap = cfg.reply_slots();
        let c = Coordinator::start(cfg, sw_factory());
        let h = c.handle();
        let vocab = ["يدرس", "قال", "ظظظ", "فتزحزحت", "سيلعبون"];
        let n = slab_cap * 8; // far past slab capacity
        let words: Vec<_> =
            vocab.iter().cycle().take(n).map(|s| ArabicWord::encode(s)).collect();
        let res = h.stem_bulk(&words).unwrap();
        assert_eq!(res.len(), n);
        // order check: every word's reply matches a direct stem
        let stemmer = Stemmer::with_defaults(Arc::new(RootSet::builtin_mini()));
        let expected = stemmer.stem_batch(&words);
        assert_eq!(res, expected);
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_errors() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        c.shutdown();
        assert!(h.submit(ArabicWord::encode("درس")).is_err());
    }

    /// Partial-submit fix: a bulk call against a closed coordinator fails
    /// fast with a clean error — no hang, no stranded replies.
    #[test]
    fn bulk_after_shutdown_errors_without_hanging() {
        let c = Coordinator::start(CoordinatorConfig::default(), sw_factory());
        let h = c.handle();
        c.shutdown();
        let words: Vec<_> = (0..64).map(|_| ArabicWord::encode("يدرس")).collect();
        let err = h.stem_bulk(&words).unwrap_err();
        assert!(format!("{err:#}").contains("closed"), "{err:#}");
        // The slab is fully recycled: a fresh coordinator-sized burst of
        // tickets is still acquirable.
        let pending_err = h.submit(ArabicWord::encode("قال"));
        assert!(pending_err.is_err());
    }

    /// Dropping a Pending un-waited abandons its ticket; the slot recycles
    /// once the worker fills it, so capacity is never leaked.
    #[test]
    fn dropped_pending_recycles_ticket() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, max_batch: 8, ..Default::default() },
            sw_factory(),
        );
        let h = c.handle();
        for _ in 0..10_000 {
            let p = h.submit(ArabicWord::encode("يدرس")).unwrap();
            drop(p); // abandon every reply
        }
        // If abandoned tickets leaked, the slab (~4096+ slots) would be
        // exhausted by now and this stream would deadlock.
        let words: Vec<_> = (0..128).map(|_| ArabicWord::encode("قال")).collect();
        let res = h.stem_stream(&words).unwrap();
        assert_eq!(res.len(), 128);
        c.shutdown();
    }

    #[test]
    fn failing_backend_reports_errors() {
        struct Failing;
        impl StemBackend for Failing {
            fn name(&self) -> &'static str {
                "failing"
            }
            fn stem_batch(&mut self, _w: &[ArabicWord]) -> Result<Vec<StemResult>> {
                anyhow::bail!("injected failure")
            }
        }
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, ..Default::default() },
            Box::new(|_| Ok(Box::new(Failing))),
        );
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("درس")).unwrap();
        assert_eq!(r, StemResult::NONE); // degraded reply, not a hang
        assert!(c.metrics().snapshot().errors >= 1);
        c.shutdown();
    }

    /// A panicking backend degrades to NONE replies instead of stranding
    /// parked waiters (slab tickets must always be filled).
    #[test]
    fn panicking_backend_degrades_instead_of_hanging() {
        struct Panicking;
        impl StemBackend for Panicking {
            fn name(&self) -> &'static str {
                "panicking"
            }
            fn stem_batch(&mut self, _w: &[ArabicWord]) -> Result<Vec<StemResult>> {
                panic!("injected panic")
            }
        }
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, ..Default::default() },
            Box::new(|_| Ok(Box::new(Panicking))),
        );
        let h = c.handle();
        let r = h.stem(ArabicWord::encode("درس")).unwrap();
        assert_eq!(r, StemResult::NONE); // degraded reply, not a hang
        assert!(c.metrics().snapshot().errors >= 1);
        c.shutdown();
    }

    /// Backend init failure: the dead worker's reject loop fails requests
    /// with NONE immediately — a live serve process degrades loudly
    /// instead of parking every client forever.
    #[test]
    fn init_failure_rejects_requests_instead_of_hanging() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 1, ..Default::default() },
            Box::new(|_| anyhow::bail!("no backend for you")),
        );
        let h = c.handle();
        // Resolves without any shutdown: the reject loop answers it.
        let r = h.stem(ArabicWord::encode("درس")).unwrap();
        assert_eq!(r, StemResult::NONE);
        assert!(c.metrics().snapshot().errors >= 2); // init + rejected request
        c.shutdown();
    }

    #[test]
    fn concurrent_clients() {
        let c = Coordinator::start(
            CoordinatorConfig { workers: 2, max_batch: 32, ..Default::default() },
            sw_factory(),
        );
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let h = c.handle();
                std::thread::spawn(move || {
                    for _ in 0..50 {
                        let r = h.stem(ArabicWord::encode("يدرس")).unwrap();
                        assert_eq!(r.root_word().to_string_ar(), "درس");
                    }
                })
            })
            .collect();
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(c.metrics().snapshot().requests, 400);
        c.shutdown();
    }
}
