//! Deterministic PRNG substrate (splitmix64) — no external `rand` crate in
//! the offline image, and we want bit-identical corpora across runs and
//! languages (`python/compile/gen_roots.py` uses the same algorithm).

/// Splitmix64: tiny, fast, and excellent statistical quality for the
/// corpus-generation / property-testing workloads here.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. Uses the widening-multiply trick (Lemire).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize index into a slice of length `n`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a reference from a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Draw from a Zipf(s) distribution over ranks `1..=n` using precomputed
/// cumulative weights. Used to give the synthetic corpus the heavy-tailed
/// root-frequency profile real text has.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `[0, n)`.
    pub fn sample(&self, rng: &mut SplitMix64) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|v| v.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sequence() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn matches_python_reference() {
        // Same constants as python/compile/gen_roots.py::_splitmix64.
        let mut r = SplitMix64::new(0);
        let z = r.next_u64();
        // python: state=0 -> z = splitmix64 step
        assert_eq!(z, 16294208416658607535);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let z = Zipf::new(1000, 1.1);
        let mut r = SplitMix64::new(3);
        let mut head = 0;
        const N: usize = 20_000;
        for _ in 0..N {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // top-10 ranks should carry a large share of the mass
        assert!(head > N / 5, "head share too small: {head}");
    }

    #[test]
    fn zipf_all_ranks_reachable_bounds() {
        let z = Zipf::new(5, 1.0);
        let mut r = SplitMix64::new(11);
        let mut seen = [false; 5];
        for _ in 0..10_000 {
            seen[z.sample(&mut r)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
