//! Benchmark harness substrate (criterion is not in the offline image):
//! warmup, adaptive iteration, mean/stddev/min, and words-per-second
//! throughput reporting in the paper's units — plus the TCP load
//! generators behind `ama loadtest`: [`run_tcp_load`] for the legacy
//! line protocol, [`run_ama1_load`] for typed AMA/1 envelopes, and
//! [`run_mostly_idle_load`] for the PR 9 C10K keepalive profile.

use crate::analysis::AnalyzeOptions;
use crate::metrics::LatencyHistogram;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use crate::chk::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    /// Words processed per iteration (for Wps reporting), if applicable.
    pub words_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn wps(&self) -> Option<f64> {
        self.words_per_iter
            .map(|w| w as f64 / self.mean.as_secs_f64())
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12.3?} ±{:>10.3?} (min {:>10.3?}, n={})",
            self.name, self.mean, self.stddev, self.min, self.iters
        )?;
        if let Some(wps) = self.wps() {
            if wps >= 1e6 {
                write!(f, "  {:>10.3} MWps", wps / 1e6)?;
            } else {
                write!(f, "  {wps:>10.1} Wps")?;
            }
        }
        Ok(())
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

/// Fast config for CI-ish runs (`AMA_BENCH_FAST=1`).
pub fn config_from_env() -> BenchConfig {
    if std::env::var_os("AMA_BENCH_FAST").is_some() {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 1_000,
        }
    } else {
        BenchConfig::default()
    }
}

/// Run `f` repeatedly; report timing statistics.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        f();
    }
    // Measure.
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < cfg.measure || (samples.len() as u64) < cfg.min_iters)
        && (samples.len() as u64) < cfg.max_iters
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n.max(1.0);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: Duration::from_secs_f64(min),
        words_per_iter: None,
    }
}

/// Like [`bench`], tagging each iteration with a word count for Wps.
pub fn bench_words<F: FnMut()>(
    name: &str,
    cfg: &BenchConfig,
    words_per_iter: u64,
    f: F,
) -> BenchResult {
    let mut r = bench(name, cfg, f);
    r.words_per_iter = Some(words_per_iter);
    r
}

/// Standard bench header so all five bench binaries print uniformly.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

// ---------------------------------------------------------------------------
// TCP load generator (the `ama loadtest` client fleet)
// ---------------------------------------------------------------------------

/// Aggregate outcome of one [`run_tcp_load`] run.
#[derive(Clone, Debug)]
pub struct LoadOutcome {
    pub conns: usize,
    /// Lines sent per write burst (1 = interactive per-word mode).
    pub depth: usize,
    /// Replies received and verified across all connections.
    pub words: u64,
    /// Client-side I/O failures (connect/read/write).
    pub errors: u64,
    /// Replies whose echoed word did not match the word sent at that
    /// position — any non-zero value means the protocol reordered.
    pub reorders: u64,
    /// Typed `UNAVAILABLE` / `RATE_LIMITED` replies tolerated by a
    /// [`run_ama1_load_tolerant`] run (the gateway shedding by design —
    /// not a failure, but not progress either). Always 0 for the strict
    /// runners.
    pub typed_shed: u64,
    pub elapsed: Duration,
    /// Client-observed round-trip latency percentiles, µs (per burst:
    /// write `depth` lines → read `depth` replies).
    pub rtt_p50_us: u64,
    pub rtt_p90_us: u64,
    pub rtt_p99_us: u64,
}

impl LoadOutcome {
    /// Aggregate throughput in words per second.
    pub fn wps(&self) -> f64 {
        if self.elapsed.is_zero() {
            return 0.0;
        }
        self.words as f64 / self.elapsed.as_secs_f64()
    }
}

impl std::fmt::Display for LoadOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "conns={} depth={} words={} -> {:.0} words/s  rtt p50={}us p90={}us p99={}us  \
             errors={} reorders={} shed={}",
            self.conns,
            self.depth,
            self.words,
            self.wps(),
            self.rtt_p50_us,
            self.rtt_p90_us,
            self.rtt_p99_us,
            self.errors,
            self.reorders,
            self.typed_shed
        )
    }
}

/// Drive the line-protocol server at `addr` from `conns` client threads
/// for `duration`. Each thread loops: write `depth` words (one per line),
/// read `depth` replies, verify each reply echoes the word sent at that
/// position (order check), record the burst round-trip latency. `depth`
/// of 1 reproduces the interactive per-word protocol; larger depths
/// exercise the pipelined mode.
pub fn run_tcp_load(
    addr: SocketAddr,
    conns: usize,
    duration: Duration,
    depth: usize,
    words: &[String],
) -> LoadOutcome {
    assert!(!words.is_empty(), "need a word list");
    // Cap the burst so write-whole-burst-then-read can never fill both
    // sockets' buffers at once (client blocked writing while the server
    // blocks writing replies = mutual deadlock). 512 words ≈ 10 KB out /
    // ~25 KB of replies, comfortably inside default loopback buffers.
    let depth = depth.clamp(1, 512);
    let hist = Arc::new(LatencyHistogram::new());
    let total_words = Arc::new(AtomicU64::new(0));
    let total_errors = Arc::new(AtomicU64::new(0));
    let total_reorders = Arc::new(AtomicU64::new(0));
    let started = Instant::now();
    let deadline = started + duration;
    // One shared copy of the word list for the whole fleet.
    let words: Arc<[String]> = words.to_vec().into();
    let threads: Vec<_> = (0..conns)
        .map(|id| {
            let words = words.clone();
            let hist = hist.clone();
            let total_words = total_words.clone();
            let total_errors = total_errors.clone();
            let total_reorders = total_reorders.clone();
            std::thread::spawn(move || {
                let run = || -> std::io::Result<()> {
                    let conn = TcpStream::connect(addr)?;
                    conn.set_nodelay(true)?;
                    // Backstop: a wedged server must not hang the harness.
                    conn.set_read_timeout(Some(Duration::from_secs(10)))?;
                    let mut writer = conn.try_clone()?;
                    let mut reader = BufReader::new(conn);
                    // Decorrelate the per-connection word streams.
                    let mut next = (id * 37) % words.len();
                    let mut burst = String::new();
                    let mut sent: Vec<usize> = Vec::with_capacity(depth);
                    let mut line = String::new();
                    while Instant::now() < deadline {
                        burst.clear();
                        sent.clear();
                        for _ in 0..depth {
                            burst.push_str(&words[next]);
                            burst.push('\n');
                            sent.push(next);
                            next = (next + 1) % words.len();
                        }
                        let t0 = Instant::now();
                        writer.write_all(burst.as_bytes())?;
                        for &wi in &sent {
                            line.clear();
                            if reader.read_line(&mut line)? == 0 {
                                return Err(std::io::Error::new(
                                    std::io::ErrorKind::UnexpectedEof,
                                    "server closed mid-burst",
                                ));
                            }
                            let echoed = line.split('\t').next().unwrap_or("");
                            if echoed != words[wi] {
                                // ord: Relaxed — stats
                                total_reorders.fetch_add(1, Ordering::Relaxed);
                            }
                            total_words.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                        }
                        hist.record(t0.elapsed());
                    }
                    let _ = writer.write_all(b"\n"); // polite close
                    Ok(())
                };
                if let Err(e) = run() {
                    eprintln!("loadtest client {id}: {e}");
                    total_errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                }
            })
        })
        .collect();
    for t in threads {
        let _ = t.join();
    }
    let elapsed = started.elapsed();
    LoadOutcome {
        conns,
        depth,
        words: total_words.load(Ordering::Relaxed), // ord: Relaxed — stats
        errors: total_errors.load(Ordering::Relaxed), // ord: Relaxed — stats
        reorders: total_reorders.load(Ordering::Relaxed), // ord: Relaxed — stats
        typed_shed: 0, // the line protocol has no typed shed frames
        elapsed,
        rtt_p50_us: hist.percentile_us(0.50),
        rtt_p90_us: hist.percentile_us(0.90),
        rtt_p99_us: hist.percentile_us(0.99),
    }
}

/// Drive the AMA/1 protocol at `addr` from `conns` typed clients for
/// `duration`. Each thread loops: send one `analyze` envelope of `depth`
/// words, read the reply, verify every result echoes its word in order,
/// record the envelope round-trip latency. Connection `i` uses
/// `opts_cycle[i % len]` — pass several option sets to exercise
/// mixed-algorithm serving. Typed server error frames count as errors
/// (they are protocol-level failures under load).
pub fn run_ama1_load(
    addr: SocketAddr,
    conns: usize,
    duration: Duration,
    depth: usize,
    words: &[String],
    opts_cycle: &[AnalyzeOptions],
) -> LoadOutcome {
    run_ama1_load_inner(addr, conns, duration, depth, words, opts_cycle, false)
}

/// [`run_ama1_load`] for gateway chaos runs: typed `UNAVAILABLE` and
/// `RATE_LIMITED` replies are counted in [`LoadOutcome::typed_shed`]
/// instead of killing the client thread — shedding is the gateway doing
/// its job during an outage. Everything else (wrong roots, reorders,
/// transport failures, any other error code) still counts as an error.
pub fn run_ama1_load_tolerant(
    addr: SocketAddr,
    conns: usize,
    duration: Duration,
    depth: usize,
    words: &[String],
    opts_cycle: &[AnalyzeOptions],
) -> LoadOutcome {
    run_ama1_load_inner(addr, conns, duration, depth, words, opts_cycle, true)
}

fn run_ama1_load_inner(
    addr: SocketAddr,
    conns: usize,
    duration: Duration,
    depth: usize,
    words: &[String],
    opts_cycle: &[AnalyzeOptions],
    tolerate_shed: bool,
) -> LoadOutcome {
    assert!(!words.is_empty(), "need a word list");
    assert!(!opts_cycle.is_empty(), "need at least one options set");
    let depth = depth.clamp(1, crate::protocol::MAX_WORDS_PER_ENVELOPE);
    let hist = Arc::new(LatencyHistogram::new());
    let total_words = Arc::new(AtomicU64::new(0));
    let total_errors = Arc::new(AtomicU64::new(0));
    let total_reorders = Arc::new(AtomicU64::new(0));
    let total_shed = Arc::new(AtomicU64::new(0));
    let started = Instant::now();
    let deadline = started + duration;
    let words: Arc<[String]> = words.to_vec().into();
    let opts_cycle: Arc<[AnalyzeOptions]> = opts_cycle.to_vec().into();
    let threads: Vec<_> = (0..conns)
        .map(|id| {
            let words = words.clone();
            let opts = opts_cycle[id % opts_cycle.len()];
            let hist = hist.clone();
            let total_words = total_words.clone();
            let total_errors = total_errors.clone();
            let total_reorders = total_reorders.clone();
            let total_shed = total_shed.clone();
            std::thread::spawn(move || {
                let run = || -> Result<(), crate::client::ClientError> {
                    let mut client = crate::client::Client::connect(addr)?;
                    client.set_read_timeout(Some(Duration::from_secs(10)))?;
                    let mut next = (id * 37) % words.len();
                    let mut batch: Vec<&str> = Vec::with_capacity(depth);
                    while Instant::now() < deadline {
                        batch.clear();
                        let mut cursor = next;
                        for _ in 0..depth {
                            batch.push(words[cursor].as_str());
                            cursor = (cursor + 1) % words.len();
                        }
                        next = cursor;
                        let t0 = Instant::now();
                        let results = match client.analyze(&batch, &opts) {
                            Ok(r) => r,
                            Err(crate::client::ClientError::Remote(err))
                                if tolerate_shed
                                    && matches!(
                                        err.code,
                                        crate::analysis::ErrorCode::Unavailable
                                            | crate::analysis::ErrorCode::RateLimited
                                    ) =>
                            {
                                total_shed.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                                continue;
                            }
                            Err(e) => return Err(e),
                        };
                        hist.record(t0.elapsed());
                        for (sent, got) in batch.iter().zip(&results) {
                            if got.word != *sent {
                                // ord: Relaxed — stats
                                total_reorders.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        if results.len() != batch.len() {
                            total_errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                        }
                        // ord: Relaxed — stats
                        total_words.fetch_add(results.len() as u64, Ordering::Relaxed);
                    }
                    Ok(())
                };
                if let Err(e) = run() {
                    eprintln!("ama1 loadtest client {id}: {e}");
                    total_errors.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                }
            })
        })
        .collect();
    for t in threads {
        let _ = t.join();
    }
    let elapsed = started.elapsed();
    LoadOutcome {
        conns,
        depth,
        words: total_words.load(Ordering::Relaxed), // ord: Relaxed — stats
        errors: total_errors.load(Ordering::Relaxed), // ord: Relaxed — stats
        reorders: total_reorders.load(Ordering::Relaxed), // ord: Relaxed — stats
        typed_shed: total_shed.load(Ordering::Relaxed), // ord: Relaxed — stats
        elapsed,
        rtt_p50_us: hist.percentile_us(0.50),
        rtt_p90_us: hist.percentile_us(0.90),
        rtt_p99_us: hist.percentile_us(0.99),
    }
}

/// PR 9 C10K mode: park `conns × idle_frac` keepalive connections (one
/// warmup word each, then silence) while the remainder run the pipelined
/// burst loop of [`run_tcp_load`]. After the burst window every parked
/// connection answers one final word — proving the event loop kept all
/// of them registered, lost nothing, and never crossed replies between
/// connections. The reported latency percentiles are the *active*
/// burst's (that is the "p99 stays flat while 1024 conns are parked"
/// comparison); idle roundtrips count toward words/errors/reorders only.
///
/// `conns` is clamped to the process fd budget (after a best-effort
/// `RLIMIT_NOFILE` raise) — check [`LoadOutcome::conns`] for the count
/// actually driven.
pub fn run_mostly_idle_load(
    addr: SocketAddr,
    conns: usize,
    idle_frac: f64,
    duration: Duration,
    depth: usize,
    words: &[String],
) -> LoadOutcome {
    assert!(!words.is_empty(), "need a word list");
    // Each parked connection costs one client fd and one server fd in
    // this same process (tests and `ama loadtest --serve` share it).
    let conns = crate::net::sys::fd_budget_conns(conns, 64).max(1);
    let idle_frac = idle_frac.clamp(0.0, 0.99);
    let active = (((conns as f64) * (1.0 - idle_frac)).ceil() as usize).clamp(1, conns);
    let idle = conns - active;
    let started = Instant::now();
    let mut idle_words = 0u64;
    let mut idle_errors = 0u64;
    let mut idle_reorders = 0u64;
    let mut line = String::new();

    // One legacy-protocol roundtrip; Ok(true) means the echo matched.
    fn roundtrip(
        writer: &mut TcpStream,
        reader: &mut BufReader<TcpStream>,
        word: &str,
        line: &mut String,
    ) -> std::io::Result<bool> {
        writer.write_all(word.as_bytes())?;
        writer.write_all(b"\n")?;
        line.clear();
        if reader.read_line(line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed a parked connection",
            ));
        }
        Ok(line.split('\t').next().unwrap_or("") == word)
    }

    // Park the idle fleet: no threads, just open sockets in a Vec —
    // exactly the population the readiness loop is built to carry.
    let mut parked: Vec<(TcpStream, BufReader<TcpStream>)> = Vec::with_capacity(idle);
    for i in 0..idle {
        let open = || -> std::io::Result<(TcpStream, BufReader<TcpStream>)> {
            let conn = TcpStream::connect(addr)?;
            conn.set_nodelay(true)?;
            conn.set_read_timeout(Some(Duration::from_secs(10)))?;
            let writer = conn.try_clone()?;
            Ok((writer, BufReader::new(conn)))
        };
        match open() {
            Ok((mut w, mut r)) => match roundtrip(&mut w, &mut r, &words[i % words.len()], &mut line) {
                Ok(ok) => {
                    idle_words += 1;
                    if !ok {
                        idle_reorders += 1;
                    }
                    parked.push((w, r));
                }
                Err(e) => {
                    eprintln!("idle warmup {i}: {e}");
                    idle_errors += 1;
                }
            },
            Err(e) => {
                eprintln!("idle connect {i}: {e}");
                idle_errors += 1;
            }
        }
    }

    // Burst window on the active slice, parked fleet riding along.
    let burst = run_tcp_load(addr, active, duration, depth, words);

    // Every parked connection must still answer on its own stream.
    for (i, (w, r)) in parked.iter_mut().enumerate() {
        match roundtrip(w, r, &words[(i + 1) % words.len()], &mut line) {
            Ok(ok) => {
                idle_words += 1;
                if !ok {
                    idle_reorders += 1;
                }
            }
            Err(e) => {
                eprintln!("idle final {i}: {e}");
                idle_errors += 1;
            }
        }
    }
    for (mut w, _r) in parked {
        let _ = w.write_all(b"\n"); // polite close
    }

    LoadOutcome {
        conns,
        depth: burst.depth,
        words: burst.words + idle_words,
        errors: burst.errors + idle_errors,
        reorders: burst.reorders + idle_reorders,
        typed_shed: 0,
        elapsed: started.elapsed(),
        rtt_p50_us: burst.rtt_p50_us,
        rtt_p90_us: burst.rtt_p90_us,
        rtt_p99_us: burst.rtt_p99_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            min_iters: 3,
            max_iters: 1000,
        };
        let mut x = 0u64;
        let r = bench_words("noop", &cfg, 100, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 3);
        assert!(r.min <= r.mean);
        assert!(r.wps().unwrap() > 0.0);
    }

    #[test]
    fn display_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean: Duration::from_micros(100),
            stddev: Duration::from_micros(5),
            min: Duration::from_micros(90),
            words_per_iter: Some(1000),
        };
        let s = format!("{r}");
        assert!(s.contains("MWps"), "{s}");
    }
}
