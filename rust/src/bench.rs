//! Benchmark harness substrate (criterion is not in the offline image):
//! warmup, adaptive iteration, mean/stddev/min, and words-per-second
//! throughput reporting in the paper's units.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    /// Words processed per iteration (for Wps reporting), if applicable.
    pub words_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn wps(&self) -> Option<f64> {
        self.words_per_iter
            .map(|w| w as f64 / self.mean.as_secs_f64())
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>12.3?} ±{:>10.3?} (min {:>10.3?}, n={})",
            self.name, self.mean, self.stddev, self.min, self.iters
        )?;
        if let Some(wps) = self.wps() {
            if wps >= 1e6 {
                write!(f, "  {:>10.3} MWps", wps / 1e6)?;
            } else {
                write!(f, "  {wps:>10.1} Wps")?;
            }
        }
        Ok(())
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

/// Fast config for CI-ish runs (`AMA_BENCH_FAST=1`).
pub fn config_from_env() -> BenchConfig {
    if std::env::var_os("AMA_BENCH_FAST").is_some() {
        BenchConfig {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(300),
            min_iters: 3,
            max_iters: 1_000,
        }
    } else {
        BenchConfig::default()
    }
}

/// Run `f` repeatedly; report timing statistics.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    while start.elapsed() < cfg.warmup {
        f();
    }
    // Measure.
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while (start.elapsed() < cfg.measure || (samples.len() as u64) < cfg.min_iters)
        && (samples.len() as u64) < cfg.max_iters
    {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n.max(1.0);
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    BenchResult {
        name: name.to_string(),
        iters: samples.len() as u64,
        mean: Duration::from_secs_f64(mean),
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: Duration::from_secs_f64(min),
        words_per_iter: None,
    }
}

/// Like [`bench`], tagging each iteration with a word count for Wps.
pub fn bench_words<F: FnMut()>(
    name: &str,
    cfg: &BenchConfig,
    words_per_iter: u64,
    f: F,
) -> BenchResult {
    let mut r = bench(name, cfg, f);
    r.words_per_iter = Some(words_per_iter);
    r
}

/// Standard bench header so all five bench binaries print uniformly.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            min_iters: 3,
            max_iters: 1000,
        };
        let mut x = 0u64;
        let r = bench_words("noop", &cfg, 100, || {
            x = x.wrapping_add(1);
            std::hint::black_box(x);
        });
        assert!(r.iters >= 3);
        assert!(r.min <= r.mean);
        assert!(r.wps().unwrap() > 0.0);
    }

    #[test]
    fn display_formats() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean: Duration::from_micros(100),
            stddev: Duration::from_micros(5),
            min: Duration::from_micros(90),
            words_per_iter: Some(1000),
        };
        let s = format!("{r}");
        assert!(s.contains("MWps"), "{s}");
    }
}
