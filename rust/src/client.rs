//! Typed AMA/1 client (PR 3): a thin, allocation-light wrapper over a
//! `TcpStream` speaking the JSON-lines protocol of [`crate::protocol`].
//!
//! Used by `ama analyze --connect`, the `ama loadtest --proto ama1`
//! fleet, and `examples/pipeline_service.rs`. One [`Client`] owns one
//! connection; requests are correlated by auto-incrementing envelope ids
//! and replies are matched strictly (an id mismatch is a protocol
//! error — this client never pipelines more than one envelope, keeping
//! it trivially correct; pipelining clients can issue multiple
//! [`Client::send`]s before [`Client::recv`]s and match ids themselves).

use crate::analysis::{AnalyzeOptions, ServeError};
use crate::protocol::{Envelope, Reply, WireResult};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure (connect/read/write/EOF).
    Io(std::io::Error),
    /// The server answered with a typed AMA/1 error frame.
    Remote(ServeError),
    /// The server's bytes did not parse as AMA/1 (or ids mismatched).
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "i/o: {e}"),
            ClientError::Remote(e) => write!(f, "server: {e}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

/// A connected AMA/1 client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    line: String,
}

impl Client {
    /// Connect and prepare the stream (TCP_NODELAY — the protocol is
    /// request/response; see server.rs on what Nagle does to that).
    pub fn connect(addr: SocketAddr) -> Result<Client, ClientError> {
        let conn = TcpStream::connect(addr)?;
        conn.set_nodelay(true)?;
        let writer = conn.try_clone()?;
        Ok(Client { reader: BufReader::new(conn), writer, next_id: 1, line: String::new() })
    }

    /// Bound how long [`Client::recv`] (and the helpers built on it) wait
    /// for a reply line.
    pub fn set_read_timeout(&mut self, d: Option<Duration>) -> Result<(), ClientError> {
        self.reader.get_ref().set_read_timeout(d)?;
        Ok(())
    }

    /// Send one envelope (assigning it the next id) without waiting for
    /// the reply; returns the id. Pair with [`Client::recv`].
    pub fn send(&mut self, mut env: Envelope) -> Result<u64, ClientError> {
        env.id = self.next_id;
        self.next_id += 1;
        let mut line = env.to_json();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        Ok(env.id)
    }

    /// Read one reply frame.
    pub fn recv(&mut self) -> Result<Reply, ClientError> {
        self.line.clear();
        let n = self.reader.read_line(&mut self.line)?;
        if n == 0 {
            return Err(ClientError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        Reply::parse(self.line.trim_end()).map_err(ClientError::Protocol)
    }

    /// Analyze a batch of words: one envelope out, one reply in. Typed
    /// server errors surface as [`ClientError::Remote`] with the wire
    /// [`ServeError`] intact.
    pub fn analyze(
        &mut self,
        words: &[&str],
        opts: &AnalyzeOptions,
    ) -> Result<Vec<WireResult>, ClientError> {
        let env = Envelope::analyze(0, words.iter().map(|w| w.to_string()).collect(), *opts);
        let id = self.send(env)?;
        match self.recv()? {
            Reply::Results { id: rid, results } if rid == id => Ok(results),
            Reply::Error { id: rid, error } if rid == id => Err(ClientError::Remote(error)),
            other => Err(ClientError::Protocol(format!(
                "reply id {} does not match request id {id}",
                other.id()
            ))),
        }
    }

    /// Liveness check: `{"op":"ping"}` → empty results.
    pub fn ping(&mut self) -> Result<(), ClientError> {
        let env = Envelope { id: 0, op: "ping".to_string(), words: Vec::new(), opts: Default::default() };
        let id = self.send(env)?;
        match self.recv()? {
            Reply::Results { id: rid, .. } if rid == id => Ok(()),
            Reply::Error { error, .. } => Err(ClientError::Remote(error)),
            other => Err(ClientError::Protocol(format!(
                "pong id {} does not match {id}",
                other.id()
            ))),
        }
    }
}
