//! Typed AMA/1 client (PR 3): a thin, allocation-light wrapper over a
//! `TcpStream` speaking the JSON-lines protocol of [`crate::protocol`].
//!
//! Used by `ama analyze --connect`, the `ama loadtest --proto ama1`
//! fleet, the PR 7 gateway's backend pool, and
//! `examples/pipeline_service.rs`. One [`Client`] owns one connection;
//! requests are correlated by auto-incrementing envelope ids and replies
//! are matched strictly (an id mismatch is a protocol error — this
//! client never pipelines more than one envelope, keeping it trivially
//! correct; pipelining clients can issue multiple [`Client::send`]s
//! before [`Client::recv`]s and match ids themselves).
//!
//! ## Reconnect-and-retry (PR 7 bugfix)
//!
//! Pre-PR 7, a replica restart poisoned a `Client` forever: the first
//! call after the restart failed with a transport error and every later
//! call failed the same way, because nothing ever re-dialed. Analysis is
//! pure (same words + options → same roots, no server-side state
//! mutation), so idempotent calls are safe to retry transparently:
//! [`Client::analyze`] and [`Client::ping`] now reconnect and resend
//! **once** on a transport failure. The single-shot primitives
//! ([`Client::analyze_once`], [`Client::send`]/[`Client::recv`]) keep the
//! old fail-fast behavior — the gateway pool uses those because it owns
//! its own retry/failover budget.

use crate::analysis::{AnalyzeOptions, ServeError};
use crate::protocol::{Envelope, Reply, WireHit, WireResult};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// Why a client call failed.
#[derive(Debug)]
pub enum ClientError {
    /// Transport failure (connect/read/write/EOF).
    Io(std::io::Error),
    /// The server answered with a typed AMA/1 error frame.
    Remote(ServeError),
    /// The server's bytes did not parse as AMA/1 (or ids mismatched).
    Protocol(String),
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "i/o: {e}"),
            ClientError::Remote(e) => write!(f, "server: {e}"),
            ClientError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

impl std::error::Error for ClientError {}

impl From<std::io::Error> for ClientError {
    fn from(e: std::io::Error) -> ClientError {
        ClientError::Io(e)
    }
}

/// A connected AMA/1 client.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    line: String,
    addr: SocketAddr,
    connect_timeout: Option<Duration>,
    read_timeout: Option<Duration>,
}

impl Client {
    /// Connect and prepare the stream (TCP_NODELAY — the protocol is
    /// request/response; see server.rs on what Nagle does to that).
    pub fn connect(addr: SocketAddr) -> Result<Client, ClientError> {
        Self::connect_with(addr, None)
    }

    /// [`Client::connect`] with a bounded dial time — the gateway pool
    /// uses this so a dead replica costs milliseconds, not the OS
    /// connect timeout.
    pub fn connect_timeout(addr: SocketAddr, timeout: Duration) -> Result<Client, ClientError> {
        Self::connect_with(addr, Some(timeout))
    }

    fn connect_with(addr: SocketAddr, timeout: Option<Duration>) -> Result<Client, ClientError> {
        let conn = match timeout {
            Some(t) => TcpStream::connect_timeout(&addr, t)?,
            None => TcpStream::connect(addr)?,
        };
        conn.set_nodelay(true)?;
        let writer = conn.try_clone()?;
        Ok(Client {
            reader: BufReader::new(conn),
            writer,
            next_id: 1,
            line: String::new(),
            addr,
            connect_timeout: timeout,
            read_timeout: None,
        })
    }

    /// The address this client dials.
    pub fn peer_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Bound how long [`Client::recv`] (and the helpers built on it) wait
    /// for a reply line. Survives [`Client::reconnect`].
    pub fn set_read_timeout(&mut self, d: Option<Duration>) -> Result<(), ClientError> {
        self.reader.get_ref().set_read_timeout(d)?;
        self.read_timeout = d;
        Ok(())
    }

    /// Drop the current stream and dial the same address again, keeping
    /// the configured timeouts. The id counter keeps counting up — ids
    /// only need to be unique per in-flight request, and a fresh server
    /// echoes whatever id it is sent.
    pub fn reconnect(&mut self) -> Result<(), ClientError> {
        let fresh = Self::connect_with(self.addr, self.connect_timeout)?;
        self.reader = fresh.reader;
        self.writer = fresh.writer;
        if self.read_timeout.is_some() {
            self.reader.get_ref().set_read_timeout(self.read_timeout)?;
        }
        Ok(())
    }

    /// Send one envelope (assigning it the next id) without waiting for
    /// the reply; returns the id. Pair with [`Client::recv`].
    pub fn send(&mut self, mut env: Envelope) -> Result<u64, ClientError> {
        env.id = self.next_id;
        self.next_id += 1;
        let mut line = env.to_json();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        Ok(env.id)
    }

    /// Read one reply frame.
    pub fn recv(&mut self) -> Result<Reply, ClientError> {
        self.line.clear();
        let n = self.reader.read_line(&mut self.line)?;
        if n == 0 {
            return Err(ClientError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "server closed the connection",
            )));
        }
        Reply::parse(self.line.trim_end()).map_err(ClientError::Protocol)
    }

    /// Analyze a batch of words: one envelope out, one reply in, with
    /// one transparent reconnect-and-retry on transport failure (analyze
    /// is idempotent — stemming is pure). Typed server errors surface as
    /// [`ClientError::Remote`] with the wire [`ServeError`] intact and
    /// are never retried here.
    pub fn analyze(
        &mut self,
        words: &[&str],
        opts: &AnalyzeOptions,
    ) -> Result<Vec<WireResult>, ClientError> {
        match self.analyze_once(words, opts) {
            Err(ClientError::Io(_)) => {
                self.reconnect()?;
                self.analyze_once(words, opts)
            }
            other => other,
        }
    }

    /// Single-shot analyze: no reconnect, no retry — fails fast on the
    /// first transport error. The gateway pool builds on this because it
    /// owns its own bounded-retry/failover budget.
    pub fn analyze_once(
        &mut self,
        words: &[&str],
        opts: &AnalyzeOptions,
    ) -> Result<Vec<WireResult>, ClientError> {
        let env = Envelope::analyze(0, words.iter().map(|w| w.to_string()).collect(), *opts);
        let id = self.send(env)?;
        match self.recv()? {
            Reply::Results { id: rid, results } if rid == id => Ok(results),
            // id 0 is the connection-scoped id: servers use it for
            // unsolicited errors (e.g. the SHUTDOWN goodbye frame).
            Reply::Error { id: rid, error } if rid == id || rid == 0 => {
                Err(ClientError::Remote(error))
            }
            other => Err(ClientError::Protocol(format!(
                "reply id {} does not match request id {id}",
                other.id()
            ))),
        }
    }

    /// Send `env` verbatim — the caller's id is preserved, not
    /// reassigned — and read one reply, which must echo that id (or the
    /// connection-scoped id 0 for unsolicited errors). The gateway's
    /// op-forwarding path uses this so front-client correlation ids
    /// survive the hop untouched.
    pub fn request_reply(&mut self, env: &Envelope) -> Result<Reply, ClientError> {
        let mut line = env.to_json();
        line.push('\n');
        self.writer.write_all(line.as_bytes())?;
        let reply = self.recv()?;
        if reply.id() == env.id || reply.id() == 0 {
            Ok(reply)
        } else {
            Err(ClientError::Protocol(format!(
                "reply id {} does not match forwarded id {}",
                reply.id(),
                env.id
            )))
        }
    }

    /// Single-shot `index` op: submit one document's tokens for
    /// server-side indexing. NOT retried — indexing mutates replica
    /// state, so a retry after an ambiguous failure could double-post.
    /// Returns `(doc_id, words_posted)`.
    pub fn index_once(
        &mut self,
        doc: &str,
        words: &[&str],
        opts: &AnalyzeOptions,
    ) -> Result<(u64, u64), ClientError> {
        let env =
            Envelope::index(0, doc, words.iter().map(|w| w.to_string()).collect(), *opts);
        let id = self.send(env)?;
        match self.recv()? {
            Reply::Indexed { id: rid, doc, posted, .. } if rid == id => Ok((doc, posted)),
            Reply::Error { id: rid, error } if rid == id || rid == 0 => {
                Err(ClientError::Remote(error))
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected reply (id {}) to index request {id}",
                other.id()
            ))),
        }
    }

    /// Single-shot `search` op: root-based retrieval over the server's
    /// index. Read-only and idempotent, but kept single-shot for
    /// symmetry with the gateway pool's own retry budget.
    pub fn search_once(
        &mut self,
        words: &[&str],
        opts: &AnalyzeOptions,
        top: Option<u64>,
    ) -> Result<Vec<WireHit>, ClientError> {
        let env =
            Envelope::search(0, words.iter().map(|w| w.to_string()).collect(), *opts, top);
        let id = self.send(env)?;
        match self.recv()? {
            Reply::Search { id: rid, hits } if rid == id => Ok(hits),
            Reply::Error { id: rid, error } if rid == id || rid == 0 => {
                Err(ClientError::Remote(error))
            }
            other => Err(ClientError::Protocol(format!(
                "unexpected reply (id {}) to search request {id}",
                other.id()
            ))),
        }
    }

    /// Liveness check: `{"op":"ping"}` → empty results. Reconnects and
    /// retries once like [`Client::analyze`].
    pub fn ping(&mut self) -> Result<(), ClientError> {
        match self.ping_once() {
            Err(ClientError::Io(_)) => {
                self.reconnect()?;
                self.ping_once()
            }
            other => other,
        }
    }

    /// Single-shot ping (the gateway's health prober: a failure here must
    /// count against the breaker, not be masked by a retry).
    pub fn ping_once(&mut self) -> Result<(), ClientError> {
        let env =
            Envelope {
                id: 0,
                op: "ping".to_string(),
                words: Vec::new(),
                opts: Default::default(),
                doc: None,
                top: None,
            };
        let id = self.send(env)?;
        match self.recv()? {
            Reply::Results { id: rid, .. } if rid == id => Ok(()),
            Reply::Error { error, .. } => Err(ClientError::Remote(error)),
            other => Err(ClientError::Protocol(format!(
                "pong id {} does not match {id}",
                other.id()
            ))),
        }
    }

    /// Discard buffered unsolicited frames (e.g. a SHUTDOWN goodbye read
    /// later than sent) — used by pools before reusing a connection.
    pub fn has_buffered_input(&self) -> bool {
        !self.reader.buffer().is_empty()
    }
}
