//! Integration tests across modules: corpus → stemmers → simulators →
//! coordinator, with the full generated dictionaries when available.

use ama::chars::ArabicWord;
use ama::coordinator::{
    BackendFactory, Coordinator, CoordinatorConfig, HwBackend,
};
use ama::corpus::{self, CorpusConfig};
use ama::hw::{DatapathConfig, NonPipelinedProcessor, PipelinedProcessor, Processor};
use ama::khoja::KhojaStemmer;
use ama::roots::RootSet;
use ama::stemmer::{MatchKind, Stemmer, StemmerConfig};
use ama::{eval, report};
use std::path::Path;
use std::sync::Arc;

fn roots() -> Arc<RootSet> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("data");
    if dir.join("roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(&dir).unwrap())
    } else {
        Arc::new(RootSet::builtin_mini())
    }
}

/// E9-adjacent: the three rust-side implementations agree word-for-word on
/// a full generated corpus (software, non-pipelined sim, pipelined sim),
/// both with and without infix processing.
#[test]
fn cross_validation_software_vs_simulators() {
    let r = roots();
    let c = corpus::generate(&r, &CorpusConfig::small(3000, 17));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();
    for infix in [false, true] {
        let sw = Stemmer::new(r.clone(), StemmerConfig { infix_processing: infix });
        let cfg = DatapathConfig { infix_units: infix };
        let expected = sw.stem_batch(&words);
        let (np, _) = NonPipelinedProcessor::new(r.clone(), cfg).run(&words);
        let (pp, _) = PipelinedProcessor::new(r.clone(), cfg).run(&words);
        assert_eq!(np, expected, "non-pipelined (infix={infix})");
        assert_eq!(pp, expected, "pipelined (infix={infix})");
    }
}

/// Table 6 phenomenon on the real corpora: infix processing lifts
/// root-level accuracy by >10 points, and both land in the paper's bands.
#[test]
fn table6_bands_on_calibrated_corpus() {
    let r = roots();
    if r.total() < 1000 {
        return; // needs the generated dictionaries
    }
    let quran = corpus::generate(&r, &CorpusConfig::quran());
    let with = Stemmer::with_defaults(r.clone());
    let without = Stemmer::new(r.clone(), StemmerConfig { infix_processing: false });
    let a = eval::evaluate(&quran, "with", |ws| with.stem_batch(ws));
    let b = eval::evaluate(&quran, "without", |ws| without.stem_batch(ws));
    // paper: 87.7% vs 71.3%
    assert!(
        (0.84..=0.93).contains(&a.root_accuracy()),
        "with-infix root accuracy {:.3} out of band",
        a.root_accuracy()
    );
    assert!(
        (0.67..=0.76).contains(&b.root_accuracy()),
        "no-infix root accuracy {:.3} out of band",
        b.root_accuracy()
    );
    assert!(a.root_accuracy() - b.root_accuracy() > 0.10);
}

/// Ankabut accuracy lands above the Quran-wide number (paper: 90.7 > 87.7).
#[test]
fn ankabut_beats_quran_accuracy() {
    let r = roots();
    if r.total() < 1000 {
        return;
    }
    let quran = corpus::generate(&r, &CorpusConfig::quran());
    let ankabut = corpus::generate(&r, &CorpusConfig::ankabut());
    let with = Stemmer::with_defaults(r.clone());
    let a = eval::evaluate(&ankabut, "with", |ws| with.stem_batch(ws));
    let q = eval::evaluate(&quran, "with", |ws| with.stem_batch(ws));
    assert!(
        a.root_accuracy() > q.root_accuracy(),
        "ankabut {:.3} <= quran {:.3}",
        a.root_accuracy(),
        q.root_accuracy()
    );
    assert!((0.86..=0.97).contains(&a.root_accuracy()), "{:.3}", a.root_accuracy());
}

/// Table 7 shape: Khoja beats the proposal on sound roots but collapses on
/// the hollow roots قول and كون, where infix processing keeps the proposal
/// competitive (the paper's 53%-better-on-كون observation).
#[test]
fn table7_hollow_root_phenomenon() {
    let r = roots();
    if r.total() < 1000 {
        return;
    }
    let quran = corpus::generate(&r, &CorpusConfig::quran());
    let kh = KhojaStemmer::new(r.clone());
    let with = Stemmer::with_defaults(r.clone());
    let without = Stemmer::new(r.clone(), StemmerConfig { infix_processing: false });
    let interest: Vec<ArabicWord> =
        ["علم", "قول", "كون"].iter().map(|s| ArabicWord::encode(s)).collect();
    let mut stemmers: Vec<(&str, Box<dyn FnMut(&[ArabicWord]) -> Vec<ama::stemmer::StemResult>>)> = vec![
        ("khoja", Box::new(|ws: &[ArabicWord]| kh.stem_batch(ws))),
        ("with", Box::new(|ws: &[ArabicWord]| with.stem_batch(ws))),
        ("without", Box::new(|ws: &[ArabicWord]| without.stem_batch(ws))),
    ];
    let rows = eval::per_root_frequency(&quran, &interest, &mut stemmers);
    let ilm = &rows[0]; // sound root علم: khoja should be strong
    assert!(ilm.counts[0] as f64 > 0.9 * ilm.actual as f64, "khoja on علم: {:?}", ilm);
    for hollow in &rows[1..] {
        // no-infix collapses on hollow roots…
        assert!(
            (hollow.counts[2] as f64) < 0.3 * hollow.actual as f64,
            "no-infix unexpectedly strong on {}: {:?}",
            hollow.root,
            hollow
        );
        // …while infix processing recovers several-fold more.
        assert!(
            hollow.counts[1] > 2 * hollow.counts[2],
            "infix gain missing on {}: {:?}",
            hollow.root,
            hollow
        );
    }
}

/// Coordinator over the HW backend: pipelined sim behind dynamic batching
/// returns the same results as direct software calls.
#[test]
fn coordinator_hw_backend_end_to_end() {
    let r = roots();
    let c = corpus::generate(&r, &CorpusConfig::small(500, 23));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();
    let sw = Stemmer::with_defaults(r.clone());
    let expected = sw.stem_batch(&words);

    let r2 = r.clone();
    let factory: BackendFactory = Box::new(move |_| {
        Ok(Box::new(HwBackend(PipelinedProcessor::new(
            r2.clone(),
            DatapathConfig { infix_units: true },
        ))))
    });
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, max_batch: 64, ..Default::default() },
        factory,
    );
    let got = coord.handle().stem_stream(&words).unwrap();
    assert_eq!(got, expected);
    coord.shutdown();
}

/// The morphology report regenerates the paper's Table 1 rows.
#[test]
fn reports_render_with_full_dictionary() {
    let r = roots();
    let t = report::table_morphology();
    assert!(t.contains("يدرسون"));
    let t = report::table_truncation(&r);
    assert!(t.contains("Trilateral"));
    let t = report::table_hw();
    assert!(t.contains("85895"));
}

/// Corpus statistics match the paper's §6.1 shape (with full dictionaries).
#[test]
fn corpus_shape_matches_paper() {
    let r = roots();
    if r.total() < 1000 {
        return;
    }
    let quran = corpus::generate(&r, &CorpusConfig::quran());
    let s = corpus::stats(&quran);
    assert_eq!(s.words, 77_476);
    assert!(
        (14_000..=26_000).contains(&s.unique_words),
        "unique words {} far from paper's 17,622",
        s.unique_words
    );
    assert!(s.unique_roots >= 1_600, "roots present {}", s.unique_roots);
}

/// Throughput invariants of the processor models (Fig 17 curve).
#[test]
fn fig17_speedup_curve_monotone() {
    let r = roots();
    let np = NonPipelinedProcessor::new(r.clone(), DatapathConfig::default());
    let pp = PipelinedProcessor::new(r, DatapathConfig::default());
    let mut prev = 0.0;
    for n in [1u64, 10, 100, 1000, 100_000] {
        let s = pp.throughput_wps(n) / np.throughput_wps(n);
        assert!(s >= prev, "speedup not monotone at {n}");
        prev = s;
    }
    assert!((prev - 5.18).abs() < 0.01, "asymptote {prev}");
    // single word: pipelining gains nothing (5 cycles either way)
    let s1 = pp.throughput_wps(1) / np.throughput_wps(1);
    assert!((s1 - 10.78 / 10.4).abs() < 1e-6);
}

/// Unknown/garbage input never panics anywhere in the stack.
#[test]
fn garbage_input_robustness() {
    let r = roots();
    let sw = Stemmer::with_defaults(r.clone());
    let kh = KhojaStemmer::new(r.clone());
    let inputs = ["", "x", "hello", "123", "ظ", "ءءءءءءءءءءءءءءءءءءءء", "اب‌جد"];
    let words: Vec<ArabicWord> = inputs.iter().map(|s| ArabicWord::encode(s)).collect();
    for w in &words {
        let _ = sw.stem(w);
        let _ = kh.stem(w);
    }
    let (res, _) = PipelinedProcessor::new(r, DatapathConfig { infix_units: true }).run(&words);
    assert_eq!(res.len(), words.len());
}

/// MatchKind round-trips through its u8 encoding (the PJRT wire format).
#[test]
fn matchkind_u8_roundtrip() {
    for k in [
        MatchKind::None,
        MatchKind::Tri,
        MatchKind::Quad,
        MatchKind::RmInfixTri,
        MatchKind::RmInfixBi,
        MatchKind::Restored,
    ] {
        assert_eq!(MatchKind::from_u8(k as u8), k);
    }
}

/// Failure injection: the runtime reports clean errors instead of
/// panicking on missing or corrupt artifacts.
#[test]
fn runtime_failure_injection() {
    use ama::runtime::Engine;
    let r = roots();
    // missing directory
    let err = Engine::load(Path::new("/nonexistent-ama-artifacts"), &r);
    assert!(err.is_err());
    let msg = format!("{:#}", err.err().unwrap());
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
    // corrupt artifact
    let dir = std::env::temp_dir().join("ama_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("stemmer_b1.hlo.txt"), "this is not HLO").unwrap();
    let err = Engine::load(&dir, &r);
    assert!(err.is_err(), "corrupt HLO must not load");
    let _ = std::fs::remove_dir_all(dir);
}

/// The runtime engine agrees with software on a corpus slice, end to end
/// through the self-hosting artifact cycle: `emit-hlo` → `Engine::load`
/// → `stem_chunk`. (Pre-PR-5 this was gated on `--features pjrt`; the
/// default build now executes artifacts through the HLO interpreter.)
/// The full-corpus check lives in `ama selftest`.
#[test]
fn runtime_matches_software_on_emitted_artifacts() {
    let dir = std::env::temp_dir().join("ama_integration_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    ama::runtime::emit::write_artifacts(&dir, &[32]).unwrap();
    let r = roots();
    let engine = ama::runtime::Engine::load(&dir, &r).unwrap();
    let c = corpus::generate(&r, &CorpusConfig::small(320, 41));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();
    let sw = Stemmer::with_defaults(r.clone());
    assert_eq!(engine.stem_chunk(&words).unwrap(), sw.stem_batch(&words));
    let _ = std::fs::remove_dir_all(&dir);
}

/// Engine batch-size selection picks the smallest artifact that fits —
/// the shared `Backend::pick_batch` (the pre-PR-5 stub disagreed with
/// the real engine here; the provided trait method is now the only
/// implementation).
#[test]
fn runtime_batch_selection() {
    let dir = std::env::temp_dir().join("ama_batch_selection_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    ama::runtime::emit::write_artifacts(&dir, ama::runtime::BATCHES).unwrap();
    let r = roots();
    let engine = ama::runtime::Engine::load(&dir, &r).unwrap();
    assert_eq!(engine.batch_sizes(), vec![1, 32, 256]);
    assert_eq!(engine.pick_batch(0), 1);
    assert_eq!(engine.pick_batch(1), 1);
    assert_eq!(engine.pick_batch(2), 32);
    assert_eq!(engine.pick_batch(33), 256);
    assert_eq!(engine.pick_batch(10_000), 256); // chunked by caller
    let _ = std::fs::remove_dir_all(&dir);
}
