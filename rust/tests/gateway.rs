//! Chaos tests for the PR 7 gateway tier: sustained load through a real
//! TCP gateway front while a backend replica is killed and restarted
//! mid-run. The acceptance bar (ISSUE PR 7):
//!
//! * zero lost or reordered replies — every request gets exactly one
//!   reply, every reply echoes its words in submission order with the
//!   roots the stemmer computes directly;
//! * clients see **only typed `UNAVAILABLE`** while capacity is gone —
//!   never a hang, a raw disconnect surfaced as garbage, or a wrong
//!   answer;
//! * the victim's breaker demonstrably walks open → half-open → closed
//!   (visible in `GatewayMetrics`), and the fleet serves again after the
//!   restart.

use ama::analysis::{AnalyzeOptions, ErrorCode};
use ama::chars::ArabicWord;
use ama::client::{Client, ClientError};
use ama::gateway::breaker::BreakerConfig;
use ama::gateway::fleet::{Fleet, FleetConfig};
use ama::gateway::pool::PoolConfig;
use ama::gateway::{Gateway, GatewayConfig, GatewayServer};
use ama::protocol::{Envelope, Reply};
use ama::rng::SplitMix64;
use ama::roots::RootSet;
use ama::stemmer::Stemmer;
use std::collections::HashMap;
use ama::chk::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const VOCAB: [&str; 6] = ["يدرس", "قال", "سيلعبون", "فتزحزحت", "يلعب", "كتب"];

/// Expected root per vocab word, computed by direct stemming against the
/// same builtin-mini dictionary the fleet serves.
fn expected_roots() -> HashMap<String, String> {
    let stemmer = Stemmer::with_defaults(Arc::new(RootSet::builtin_mini()));
    VOCAB
        .iter()
        .map(|w| {
            let res = stemmer.stem(&ArabicWord::encode(w));
            (w.to_string(), res.root_word().to_string_ar())
        })
        .collect()
}

/// Snappy failure detection + recovery so the whole chaos cycle fits in
/// a couple of seconds of test time.
fn chaos_cfg() -> GatewayConfig {
    GatewayConfig {
        poll: Duration::from_millis(10),
        request_deadline: Duration::from_secs(2),
        probe_interval: Duration::from_millis(25),
        pool: PoolConfig {
            breaker: BreakerConfig {
                failure_threshold: 2,
                cooldown: Duration::from_millis(150),
            },
            attempts_per_endpoint: 2,
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(5),
            connect_timeout: Duration::from_millis(100),
            idle_per_endpoint: 4,
        },
        ..GatewayConfig::default()
    }
}

/// The headline chaos test: 4 concurrent clients sustain batched load
/// through the TCP gateway front against 3 replicas while replica 0 is
/// killed and later restarted. Failover should absorb almost everything;
/// whatever cannot be absorbed must surface as typed `UNAVAILABLE`.
#[test]
fn chaos_kill_and_restart_replica_under_load_loses_nothing() {
    const CLIENTS: usize = 4;
    let expected = expected_roots();

    let mut fleet = Fleet::start(3, FleetConfig::mini());
    let gw = Arc::new(Gateway::new(fleet.addrs(), chaos_cfg()));
    let server = Arc::new(GatewayServer::bind("127.0.0.1:0", gw.clone()).unwrap());
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());

    let stop = Arc::new(AtomicBool::new(false));
    let workers: Vec<_> = (0..CLIENTS)
        .map(|id| {
            let stop = stop.clone();
            let expected = expected.clone();
            std::thread::spawn(move || -> (u64, u64) {
                let mut client = Client::connect(addr).unwrap();
                client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                let mut rng = SplitMix64::new(0xC1A0 + id as u64);
                let (mut ok, mut unavailable) = (0u64, 0u64);
                // ord: Acquire — stop-flag poll pairing with the Release store.
                while !stop.load(Ordering::Acquire) {
                    // 1–4 words per envelope, rotating through the vocab
                    let n = 1 + rng.index(4);
                    let batch: Vec<&str> =
                        (0..n).map(|_| VOCAB[rng.index(VOCAB.len())]).collect();
                    match client.analyze_once(&batch, &AnalyzeOptions::default()) {
                        Ok(results) => {
                            assert_eq!(results.len(), batch.len(), "client {id}: lost words");
                            for (w, r) in batch.iter().zip(&results) {
                                assert_eq!(&r.word, w, "client {id}: reply out of order");
                                assert_eq!(
                                    &r.root, &expected[*w],
                                    "client {id}: wrong root for {w}"
                                );
                            }
                            ok += 1;
                        }
                        // The only acceptable failure while capacity is
                        // gone: typed, retryable, with a retry hint.
                        Err(ClientError::Remote(err)) => {
                            assert_eq!(
                                err.code,
                                ErrorCode::Unavailable,
                                "client {id}: non-UNAVAILABLE error under chaos: {err}"
                            );
                            assert!(
                                err.meta.and_then(|m| m.retry_after_ms).is_some(),
                                "client {id}: UNAVAILABLE without a retry hint"
                            );
                            unavailable += 1;
                        }
                        Err(other) => {
                            panic!("client {id}: untyped failure under chaos: {other}")
                        }
                    }
                }
                (ok, unavailable)
            })
        })
        .collect();

    // Chaos choreography: let load flow, kill replica 0, leave it dark
    // long enough for its breaker to trip (prober probes every 25 ms,
    // threshold 2), then restart it on the same port and give the
    // half-open path time to close the breaker again.
    std::thread::sleep(Duration::from_millis(300));
    fleet.kill(0);
    std::thread::sleep(Duration::from_millis(500));
    fleet.restart(0);
    std::thread::sleep(Duration::from_millis(500));
    stop.store(true, Ordering::Release); // ord: Release — stop flag

    let mut total_ok = 0u64;
    let mut total_unavailable = 0u64;
    for w in workers {
        let (ok, unavailable) = w.join().unwrap();
        assert!(ok > 0, "a client made no progress at all");
        total_ok += ok;
        total_unavailable += unavailable;
    }
    assert!(total_ok > 50, "suspiciously little traffic flowed: {total_ok}");

    // With two healthy replicas the ring failover should absorb the
    // outage almost entirely.
    assert!(
        total_unavailable <= total_ok / 4,
        "failover barely worked: {total_unavailable} unavailable vs {total_ok} ok"
    );

    // The victim's breaker visibly walked the full cycle.
    let snap = gw.metrics().snapshot();
    assert!(snap.breaker_opened >= 1, "breaker never opened: {snap:?}");
    assert!(snap.breaker_half_opened >= 1, "breaker never half-opened: {snap:?}");
    assert!(snap.breaker_closed >= 1, "breaker never closed again: {snap:?}");
    assert!(snap.probe_failures >= 1, "the prober never saw the outage: {snap:?}");

    // Fully recovered: a fresh client round-trips through every shard.
    let mut client = Client::connect(addr).unwrap();
    client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let all: Vec<&str> = VOCAB.to_vec();
    let results = client.analyze(&all, &AnalyzeOptions::default()).unwrap();
    for (w, r) in all.iter().zip(&results) {
        assert_eq!(&r.root, &expected[*w], "post-recovery wrong root for {w}");
    }

    server.stop();
    serve_thread.join().unwrap().unwrap();
    fleet.shutdown();
}

/// Breaker lifecycle against a single replica, where failover cannot
/// mask the outage: every request during the dark window must come back
/// as typed `UNAVAILABLE` (with retry metadata), and after the restart
/// the prober's half-open trial closes the breaker with no client help.
#[test]
fn single_replica_outage_is_typed_unavailable_then_recovers() {
    let mut fleet = Fleet::start(1, FleetConfig::mini());
    let cfg = GatewayConfig {
        pool: PoolConfig {
            attempts_per_endpoint: 1, // keep the dark-window loop fast
            ..chaos_cfg().pool
        },
        request_deadline: Duration::from_millis(500),
        ..chaos_cfg()
    };
    let gw = Gateway::new(fleet.addrs(), cfg);
    let bucket = gw.client_bucket();
    let mut rng = SplitMix64::new(7);
    let mut next_id = 0u64;
    let mut request = |gw: &Gateway, rng: &mut SplitMix64, id: &mut u64| -> Reply {
        *id += 1;
        let env = Envelope::analyze(*id, vec!["سيلعبون".to_string()], AnalyzeOptions::default());
        Reply::parse(&gw.serve_line(&env.to_json(), &bucket, rng)).unwrap()
    };

    // healthy
    match request(&gw, &mut rng, &mut next_id) {
        Reply::Results { results, .. } => assert_eq!(results[0].root, "لعب"),
        other => panic!("healthy fleet failed: {other:?}"),
    }

    // dark: every reply is UNAVAILABLE + retry hint — nothing else
    fleet.kill(0);
    let dark_until = Instant::now() + Duration::from_millis(400);
    let mut dark_replies = 0u64;
    while Instant::now() < dark_until {
        match request(&gw, &mut rng, &mut next_id) {
            Reply::Error { error, .. } => {
                assert_eq!(error.code, ErrorCode::Unavailable, "dark window: {error}");
                let retry = error.meta.and_then(|m| m.retry_after_ms);
                assert!(retry.is_some(), "UNAVAILABLE without retry_after_ms");
                dark_replies += 1;
            }
            other => panic!("impossible success with zero replicas: {other:?}"),
        }
        // with the breaker open each reply is near-instant; don't spin
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(dark_replies >= 3, "dark window produced almost no traffic");
    let mid = gw.metrics().snapshot();
    assert!(mid.breaker_opened >= 1, "breaker never opened: {mid:?}");
    assert_eq!(mid.breaker_closed, 0, "nothing should close while dark");
    assert!(mid.unavailable >= 1, "unavailable counter never moved");

    // restart: the background prober alone must close the breaker
    fleet.restart(0);
    let recovered_by = Instant::now() + Duration::from_secs(3);
    loop {
        match request(&gw, &mut rng, &mut next_id) {
            Reply::Results { results, .. } => {
                assert_eq!(results[0].root, "لعب");
                break;
            }
            Reply::Error { error, .. } => {
                assert_eq!(error.code, ErrorCode::Unavailable, "recovery window: {error}");
                assert!(Instant::now() < recovered_by, "never recovered after restart");
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }
    let snap = gw.metrics().snapshot();
    assert!(snap.breaker_half_opened >= 1, "no half-open trial recorded: {snap:?}");
    assert!(snap.breaker_closed >= 1, "breaker never closed: {snap:?}");
    fleet.shutdown();
}

/// Cross-connection coalescing: concurrent envelopes for the same word
/// through the TCP front collapse onto fewer backend dispatches, and
/// every follower still gets a correct, correctly-echoed reply.
#[test]
fn concurrent_identical_requests_coalesce() {
    const CLIENTS: usize = 8;
    const ROUNDS: usize = 30;
    let fleet = Fleet::start(1, FleetConfig::mini());
    let gw = Arc::new(Gateway::new(fleet.addrs(), chaos_cfg()));
    let server = Arc::new(GatewayServer::bind("127.0.0.1:0", gw.clone()).unwrap());
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());

    let workers: Vec<_> = (0..CLIENTS)
        .map(|_| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                client.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                for _ in 0..ROUNDS {
                    // all clients hammer the same word at the same time
                    let r = client.analyze(&["سيلعبون"], &AnalyzeOptions::default()).unwrap();
                    assert_eq!(r[0].word, "سيلعبون");
                    assert_eq!(r[0].root, "لعب");
                }
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }

    let snap = gw.metrics().snapshot();
    assert_eq!(snap.words, (CLIENTS * ROUNDS) as u64);
    // Coalescing is timing-dependent; with 8 clients racing the same key
    // at least *some* overlap must have been captured.
    assert!(
        snap.coalesced_words > 0,
        "8 clients × 30 rounds on one word never overlapped: {snap:?}"
    );
    assert_eq!(
        snap.backend_words + snap.coalesced_words,
        snap.words,
        "every word is either dispatched or coalesced: {snap:?}"
    );

    server.stop();
    serve_thread.join().unwrap().unwrap();
    fleet.shutdown();
}
