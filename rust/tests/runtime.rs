//! Runtime-engine integration tests (PR 5): the self-hosting artifact
//! cycle (emit → load → execute), engine chunking edge cases that had
//! never executed anywhere, and the `--backend runtime` serving path.

use ama::chars::{ArabicWord, MAX_WORD};
use ama::coordinator::{Coordinator, CoordinatorConfig, RuntimeBackend};
use ama::rng::SplitMix64;
use ama::roots::RootSet;
use ama::runtime::{emit, Engine};
use ama::stemmer::Stemmer;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn roots() -> Arc<RootSet> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("data");
    if dir.join("roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(&dir).unwrap())
    } else {
        Arc::new(RootSet::builtin_mini())
    }
}

/// Emit a fresh artifact set into a unique temp dir.
fn emitted_artifacts(tag: &str, batches: &[usize]) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ama_runtime_test_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    emit::write_artifacts(&dir, batches).unwrap();
    dir
}

fn random_words(n: usize, seed: u64) -> Vec<ArabicWord> {
    let mut rng = SplitMix64::new(seed);
    (0..n)
        .map(|_| {
            let len = rng.index(MAX_WORD + 1);
            let codes: Vec<u16> =
                (0..len).map(|_| ama::chars::index_char(1 + rng.below(36) as u8)).collect();
            ArabicWord::from_codes(&codes)
        })
        .collect()
}

/// Chunking edge cases (these paths had never executed anywhere before
/// PR 5): n = 0, n exactly a loaded batch size, n one past a batch size,
/// and n far beyond the largest batch (multi-chunk with a short tail).
#[test]
fn stem_chunk_edge_cases() {
    let dir = emitted_artifacts("chunking", ama::runtime::BATCHES);
    let r = roots();
    let engine = Engine::load(&dir, &r).unwrap();
    let sw = Stemmer::with_defaults(r.clone());

    // n = 0: no executable runs at all.
    assert!(engine.stem_chunk(&[]).unwrap().is_empty());

    let words = random_words(600, 0x0917_0061);
    for n in [1usize, 2, 31, 32, 33, 255, 256, 257, 600] {
        let slice = &words[..n];
        let got = engine.stem_chunk(slice).unwrap();
        assert_eq!(got.len(), n, "n={n}: result length");
        assert_eq!(got, sw.stem_batch(slice), "n={n}: results");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Words shorter than the batch width survive the pad → execute → decode
/// roundtrip: a 3-word chunk through the 32-wide executable returns
/// exactly 3 results, identical to the software kernel, and the padded
/// tail never leaks into them.
#[test]
fn short_chunk_pad_decode_roundtrip() {
    let dir = emitted_artifacts("padding", &[32]);
    let r = roots();
    let engine = Engine::load(&dir, &r).unwrap();
    assert_eq!(engine.batch_sizes(), vec![32]);
    let sw = Stemmer::with_defaults(r.clone());
    let words: Vec<ArabicWord> =
        ["سيلعبون", "قال", "ظظظ"].iter().map(|s| ArabicWord::encode(s)).collect();
    let got = engine.stem_chunk(&words).unwrap();
    assert_eq!(got.len(), 3);
    assert_eq!(got, sw.stem_batch(&words));
    assert_eq!(got[0].root_word().to_string_ar(), "لعب");
    assert_eq!(got[1].root_word().to_string_ar(), "قول");
    assert_eq!(got[2], ama::stemmer::StemResult::NONE);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A batch-1-only artifact set still serves any n (everything chunks to
/// width 1), pinning the `pick_batch` largest-available fallback.
#[test]
fn single_batch_artifact_chunks_everything() {
    let dir = emitted_artifacts("b1only", &[1]);
    let r = roots();
    let engine = Engine::load(&dir, &r).unwrap();
    assert_eq!(engine.pick_batch(10_000), 1);
    let words = random_words(40, 0x0917_0062);
    let sw = Stemmer::with_defaults(r.clone());
    assert_eq!(engine.stem_chunk(&words).unwrap(), sw.stem_batch(&words));
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--backend runtime` end to end: the coordinator builds the (non-Send)
/// engine on its worker thread and serves batches through it, word-for-
/// word identical to the software backend.
#[test]
fn runtime_backend_serves_through_coordinator() {
    let dir = emitted_artifacts("serve", &[1, 32]);
    let r = roots();
    let sw = Stemmer::with_defaults(r.clone());
    let words = random_words(300, 0x0917_0063);
    let expected = sw.stem_batch(&words);

    let (dir2, r2) = (dir.clone(), r.clone());
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 1, max_batch: 32, ..Default::default() },
        Box::new(move |_| Ok(Box::new(RuntimeBackend(Engine::load(&dir2, &r2)?)))),
    );
    let got = coord.handle().stem_bulk(&words).unwrap();
    assert_eq!(got, expected);
    assert_eq!(coord.metrics().snapshot().words, words.len() as u64);
    coord.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
