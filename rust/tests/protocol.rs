//! AMA/1 wire-protocol tests over real TCP: mixed-algorithm serving,
//! interleaved AMA/1 + legacy-line connections on one server, typed
//! error codes end to end, and per-request option handling — the PR 3
//! acceptance pins.

use ama::analysis::{Algorithm, AnalyzeOptions, ErrorCode};
use ama::chars::ArabicWord;
use ama::client::{Client, ClientError};
use ama::coordinator::{Coordinator, CoordinatorConfig};
use ama::khoja::KhojaStemmer;
use ama::light::{LightStemmer, VotingAnalyzer};
use ama::protocol::Reply;
use ama::roots::RootSet;
use ama::server::Server;
use ama::stemmer::{MatchKind, StemResult, Stemmer, StemmerConfig};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::thread::JoinHandle;

struct Stack {
    coord: Option<Coordinator>,
    server: Option<Arc<Server>>,
    serve_thread: Option<JoinHandle<anyhow::Result<()>>>,
    addr: std::net::SocketAddr,
    roots: Arc<RootSet>,
}

fn start_stack() -> Stack {
    let roots = Arc::new(RootSet::builtin_mini());
    let coord = Coordinator::start_registry(
        CoordinatorConfig { workers: 2, max_batch: 64, ..Default::default() },
        roots.clone(),
        StemmerConfig::default(),
    );
    let server = Arc::new(Server::bind("127.0.0.1:0", coord.handle()).unwrap());
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());
    Stack {
        coord: Some(coord),
        server: Some(server),
        serve_thread: Some(serve_thread),
        addr,
        roots,
    }
}

impl Drop for Stack {
    fn drop(&mut self) {
        if let Some(server) = self.server.take() {
            server.stop();
        }
        if let Some(t) = self.serve_thread.take() {
            t.join().unwrap().unwrap();
        }
        if let Some(c) = self.coord.take() {
            c.shutdown();
        }
    }
}

/// The legacy reply line the pre-PR-3 server produced for `word` — the
/// bare-line wire format is pinned byte for byte.
fn legacy_line(stemmer: &Stemmer, word: &str) -> String {
    let r = stemmer.stem(&ArabicWord::encode(word));
    format!("{word}\t{}\t{}\t{}", r.root_word().to_string_ar(), r.kind as u8, r.cut)
}

/// Acceptance: one running server instance answers AMA/1 requests for
/// all four algorithms (per-request `algorithm` + infix honored) while
/// raw bare-line sessions against the same port keep returning roots
/// unchanged — all connections interleaved and concurrent.
#[test]
fn mixed_algorithms_and_legacy_interleaved_on_one_server() {
    let stack = start_stack();
    let vocab = ["يدرس", "قال", "دارس", "والدرس", "مدروس", "سيلعبون", "ظظظ"];
    // AMA/1 rejects structurally un-analyzable words with BAD_WORD, so
    // the typed fleet uses the Arabic-only slice (ظظظ is valid Arabic —
    // it just has no root).
    let r = stack.roots.clone();
    let lb = Stemmer::with_defaults(r.clone());
    let kh = KhojaStemmer::new(r.clone());
    let li = LightStemmer::new(r.clone());
    let vo = VotingAnalyzer::new(r.clone());
    let direct: Vec<(Algorithm, Vec<StemResult>)> = vec![
        (
            Algorithm::Linguistic,
            vocab.iter().map(|w| lb.stem(&ArabicWord::encode(w))).collect(),
        ),
        (Algorithm::Khoja, vocab.iter().map(|w| kh.stem(&ArabicWord::encode(w))).collect()),
        (Algorithm::Light, vocab.iter().map(|w| li.stem(&ArabicWord::encode(w))).collect()),
        (Algorithm::Voting, vocab.iter().map(|w| vo.stem(&ArabicWord::encode(w))).collect()),
    ];

    let addr = stack.addr;
    let mut threads: Vec<JoinHandle<()>> = Vec::new();
    // Four AMA/1 clients, one per algorithm, hammering concurrently.
    for (algo, expected) in direct.clone() {
        threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            let opts = AnalyzeOptions::with_algorithm(algo);
            for _ in 0..25 {
                let results = client.analyze(&vocab, &opts).unwrap();
                assert_eq!(results.len(), vocab.len());
                for ((w, got), want) in vocab.iter().zip(&results).zip(&expected) {
                    assert_eq!(got.word, *w, "{algo}: echo mismatch");
                    assert_eq!(got.algo, algo);
                    assert_eq!(got.kind, want.kind, "{algo} on {w}");
                    let want_root = if want.kind == MatchKind::None {
                        String::new()
                    } else {
                        want.root_word().to_string_ar()
                    };
                    assert_eq!(got.root, want_root, "{algo} on {w}");
                }
            }
        }));
    }
    // Three concurrent legacy bare-line clients on the same port.
    let lb_expected: Vec<String> = vocab.iter().map(|w| legacy_line(&lb, w)).collect();
    for _ in 0..3 {
        let lb_expected = lb_expected.clone();
        threads.push(std::thread::spawn(move || {
            let conn = TcpStream::connect(addr).unwrap();
            conn.set_nodelay(true).unwrap();
            let mut writer = conn.try_clone().unwrap();
            let mut reader = BufReader::new(conn);
            let mut line = String::new();
            for _ in 0..25 {
                for (w, want) in vocab.iter().zip(&lb_expected) {
                    writeln!(writer, "{w}").unwrap();
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    assert_eq!(line.trim_end(), want, "legacy reply changed");
                }
            }
            writer.write_all(b"\n").unwrap();
        }));
    }
    for t in threads {
        t.join().unwrap();
    }

    // Strictly interleaved from a single thread: AMA/1 envelope, then a
    // legacy line, alternating over two live connections.
    let mut client = Client::connect(addr).unwrap();
    let legacy = TcpStream::connect(addr).unwrap();
    legacy.set_nodelay(true).unwrap();
    let mut legacy_writer = legacy.try_clone().unwrap();
    let mut legacy_reader = BufReader::new(legacy);
    let mut line = String::new();
    for (i, &w) in vocab.iter().cycle().take(20).enumerate() {
        let algo = Algorithm::ALL[i % 4];
        let results = client.analyze(&[w], &AnalyzeOptions::with_algorithm(algo)).unwrap();
        assert_eq!(results[0].word, w);
        writeln!(legacy_writer, "{w}").unwrap();
        line.clear();
        legacy_reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), legacy_line(&lb, w));
    }
    legacy_writer.write_all(b"\n").unwrap();
}

/// Typed error codes end to end: malformed frames, unknown ops, bad
/// versions, and BAD_WORD all come back as in-band error frames with the
/// right code, and the connection survives every one of them.
#[test]
fn error_codes_over_tcp() {
    let stack = start_stack();
    let conn = TcpStream::connect(stack.addr).unwrap();
    conn.set_nodelay(true).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut line = String::new();

    let mut roundtrip = |frame: &str| -> Reply {
        writeln!(writer, "{frame}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        Reply::parse(line.trim_end()).unwrap()
    };

    let code = |r: Reply| match r {
        Reply::Error { error, .. } => error.code,
        Reply::Results { .. } => panic!("expected an error frame"),
    };

    assert_eq!(code(roundtrip(r#"{"op":"analyze","words":"#)), ErrorCode::BadRequest);
    assert_eq!(code(roundtrip(r#"{"id":4,"op":"explode"}"#)), ErrorCode::UnknownOp);
    assert_eq!(code(roundtrip(r#"{"v":9,"id":5,"op":"analyze","words":[]}"#)), ErrorCode::BadVersion);
    assert_eq!(
        code(roundtrip(r#"{"id":6,"op":"analyze","words":["hello"]}"#)),
        ErrorCode::BadWord
    );
    assert_eq!(
        code(roundtrip(r#"{"id":7,"op":"analyze","words":[""]}"#)),
        ErrorCode::BadWord
    );

    // error ids echo for correlation
    match roundtrip(r#"{"id":6,"op":"analyze","words":["hello"]}"#) {
        Reply::Error { id, .. } => assert_eq!(id, 6),
        _ => unreachable!(),
    }

    // the connection still serves good requests afterwards
    match roundtrip(r#"{"id":8,"op":"analyze","words":["قال"]}"#) {
        Reply::Results { id, results } => {
            assert_eq!(id, 8);
            assert_eq!(results[0].root, "قول");
        }
        Reply::Error { error, .. } => panic!("healthy frame failed: {error}"),
    }

    // BAD_WORD rejections surfaced in the coordinator metrics
    let snap = stack.coord.as_ref().unwrap().metrics().snapshot();
    assert!(snap.rejected_bad_word >= 3, "bad_word rejections uncounted: {snap}");

    writer.write_all(b"\n").unwrap();
}

/// Per-request infix override and trace over the wire.
#[test]
fn infix_and_trace_options_over_tcp() {
    let stack = start_stack();
    let mut client = Client::connect(stack.addr).unwrap();

    // قال is only analyzable with infix processing (Restore Original Form)
    let on = client.analyze(&["قال"], &AnalyzeOptions::default()).unwrap();
    assert_eq!(on[0].kind, MatchKind::Restored);
    assert_eq!(on[0].root, "قول");
    assert!(on[0].trace.is_none());

    let off = client
        .analyze(
            &["قال"],
            &AnalyzeOptions { infix: Some(false), ..Default::default() },
        )
        .unwrap();
    assert_eq!(off[0].kind, MatchKind::None);
    assert_eq!(off[0].root, "");

    let traced = client
        .analyze(
            &["سيلعبون"],
            &AnalyzeOptions { want_trace: true, ..Default::default() },
        )
        .unwrap();
    let trace = traced[0].trace.as_ref().expect("trace requested");
    let stages: Vec<&str> = trace.iter().map(|(s, _)| s.as_str()).collect();
    assert_eq!(stages, ama::analysis::STAGE_NAMES);

    // voting metadata crosses the wire
    let voted = client
        .analyze(&["درس"], &AnalyzeOptions::with_algorithm(Algorithm::Voting))
        .unwrap();
    assert_eq!(voted[0].votes, 3);
    assert!((voted[0].confidence - 1.0).abs() < 1e-3);
}

/// The typed client surfaces remote typed errors as `Remote` and
/// oversized envelopes are rejected with BAD_REQUEST.
#[test]
fn client_error_surface() {
    let stack = start_stack();
    let mut client = Client::connect(stack.addr).unwrap();

    match client.analyze(&["not-arabic"], &AnalyzeOptions::default()) {
        Err(ClientError::Remote(e)) => assert_eq!(e.code, ErrorCode::BadWord),
        other => panic!("expected Remote(BAD_WORD), got {other:?}"),
    }

    let too_many: Vec<&str> = vec!["درس"; ama::protocol::MAX_WORDS_PER_ENVELOPE + 1];
    match client.analyze(&too_many, &AnalyzeOptions::default()) {
        Err(ClientError::Remote(e)) => assert_eq!(e.code, ErrorCode::BadRequest),
        other => panic!("expected Remote(BAD_REQUEST), got {other:?}"),
    }

    // ping still works afterwards
    client.ping().unwrap();
}
