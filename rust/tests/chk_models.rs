//! Exhaustive small-bound concurrency models for the lock-free core
//! (PR 10), run under the vendored `chk` explorer:
//!
//! ```text
//! cargo test --features chk --test chk_models      # `make chk`
//! ```
//!
//! Each `chk::model(..)` closure is executed once per explored thread
//! interleaving (DFS over every scheduling decision and every
//! coherence-allowed load value — see `rust/src/chk/`), so a plain
//! `assert!` inside the closure is a claim over *all* interleavings at
//! this bound. The `model_expect_failure` tests are the checker's
//! sensitivity proof: they deliberately weaken an ordering the
//! production code relies on and assert that exploration *does* find a
//! failing schedule — if the checker ever stops catching those, these
//! tests go red before the production protocols do.
//!
//! Models stay tiny (2–3 threads, ≤6 visible ops each) on purpose:
//! loom-style exploration is exponential in visible ops, and every
//! protocol bug class we care about (lost update, torn seqlock read,
//! missed wakeup, double recycle, dropped close) already shows up at
//! this bound.

#![cfg(feature = "chk")]

use std::time::Duration;

use ama::chk;
use ama::chk::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, Ordering};
use ama::chk::sync::{Arc, Mutex};
use ama::chk::time::Instant;

// ---------------------------------------------------------------------------
// Checker self-tests: the message-passing litmus pair
// ---------------------------------------------------------------------------

/// Release/acquire message passing is correct — the checker must agree.
#[test]
fn litmus_mp_release_acquire_passes() {
    chk::model(|| {
        let data = Arc::new(AtomicU32::new(0));
        let flag = Arc::new(AtomicBool::new(false));
        let t = {
            let (data, flag) = (data.clone(), flag.clone());
            chk::thread::spawn(move || {
                data.store(42, Ordering::Relaxed); // ord: Relaxed — published by the Release below
                flag.store(true, Ordering::Release); // ord: Release — publishes `data`
            })
        };
        // ord: Acquire — synchronizes with the Release store above.
        if flag.load(Ordering::Acquire) {
            assert_eq!(data.load(Ordering::Relaxed), 42); // ord: Relaxed — ordered by the flag
        }
        t.join().unwrap();
    });
}

/// The same handshake with a Relaxed flag is broken: an acquiring-free
/// reader may see `flag == true` but stale `data == 0`. The explorer
/// must find that schedule — this is the checker's sensitivity proof
/// for `Relaxed` vs `Acquire/Release` visibility.
#[test]
fn litmus_mp_relaxed_fails() {
    let report = chk::model_expect_failure(|| {
        let data = Arc::new(AtomicU32::new(0));
        let flag = Arc::new(AtomicBool::new(false));
        let t = {
            let (data, flag) = (data.clone(), flag.clone());
            chk::thread::spawn(move || {
                data.store(42, Ordering::Relaxed); // ord: Relaxed — deliberately unpublished
                flag.store(true, Ordering::Relaxed); // ord: Relaxed — deliberately weakened
            })
        };
        // ord: Relaxed — deliberately weakened: no sync edge.
        if flag.load(Ordering::Relaxed) {
            assert_eq!(data.load(Ordering::Relaxed), 42); // ord: Relaxed — may see stale 0
        }
        t.join().unwrap();
    });
    assert!(report.contains("assert"), "unexpected failure report: {report}");
}

// ---------------------------------------------------------------------------
// Model 1 — ReplySlab: fill vs wait_timeout-abandon race
// ---------------------------------------------------------------------------

/// The fill-vs-abandon race on one slot: whoever loses the
/// `state.swap` hands the slot to the other side, and the slot must be
/// recycled exactly once (a double free would corrupt the Treiber
/// freelist; a leak would shrink the slab). Explored outcomes:
/// reply delivered, or timeout with the filler recycling.
#[test]
fn slab_fill_vs_abandon_recycles_exactly_once() {
    chk::model(|| {
        let slab = ama::exec::ReplySlab::<u32>::new(2);
        let ticket = slab.try_acquire().expect("fresh slab has a free slot");
        let filler = {
            let slab = slab.clone();
            chk::thread::spawn(move || slab.fill(ticket, 7))
        };
        match slab.wait_timeout(ticket, Duration::from_millis(1)) {
            Ok(v) => assert_eq!(v, 7),
            Err(ama::exec::QueueError::Timeout) => {} // filler recycles
            Err(e) => panic!("unexpected slab error: {e:?}"),
        }
        filler.join().unwrap();
        // Exactly-once recycle: both slots acquirable, and no phantom
        // third slot (a double push of the same index would produce one
        // or corrupt the freelist into losing one).
        let a = slab.try_acquire().expect("slot 1 back on the freelist");
        let b = slab.try_acquire().expect("slot 2 back on the freelist");
        assert!(slab.try_acquire().is_none(), "freelist grew a phantom slot");
        slab.release_unused(a);
        slab.release_unused(b);
    });
}

// ---------------------------------------------------------------------------
// Model 2 — StemCache seqlock: reader vs writer vs CAS-loser
// ---------------------------------------------------------------------------

fn analysis_with_root(r: u16) -> ama::analysis::Analysis {
    ama::analysis::Analysis::from_result(
        ama::StemResult { root: [r, r, r, r], kind: ama::MatchKind::Tri, cut: 1 },
        ama::analysis::Algorithm::Linguistic,
    )
}

/// Two writers race the version-CAS for the same slot while a reader
/// runs the seqlock protocol. The reader must never observe a torn
/// value (half of writer A's payload, half of writer B's): it sees
/// `None` or one of the two complete analyses. After both writers
/// retire, the slot must hold one complete value — the CAS loser drops
/// its insert (`seqlock_cas_loser` is the eviction-free guarantee).
#[test]
fn seqlock_reader_never_tears_and_cas_loser_drops_insert() {
    chk::model(|| {
        let cache = ama::StemCache::new(1);
        let w = ama::PackedWord(0x0641_0042_0043u128);
        let opts = ama::analysis::EngineOpts::default();
        let a1 = analysis_with_root(100);
        let a2 = analysis_with_root(200);
        let w1 = {
            let (cache, a1) = (cache.clone(), a1.clone());
            chk::thread::spawn(move || cache.insert(w, opts, &a1))
        };
        let w2 = {
            let (cache, a2) = (cache.clone(), a2.clone());
            chk::thread::spawn(move || cache.insert(w, opts, &a2))
        };
        // Reader: any result must be one of the two complete payloads.
        if let Some(got) = cache.lookup(w, opts) {
            assert!(got == a1 || got == a2, "torn seqlock read: {got:?}");
        }
        w1.join().unwrap();
        w2.join().unwrap();
        // CAS loser dropped its insert; the winner's payload is intact.
        let fin = cache.lookup(w, opts).expect("a completed insert is visible");
        assert!(fin == a1 || fin == a2, "torn value after quiescence: {fin:?}");
    });
}

/// Hand-rolled seqlock with the production orderings (the shape
/// `cache.rs` uses: Acquire entry load, Relaxed data loads certified by
/// an Acquire fence + Relaxed re-check; writer claims odd, Release
/// fence, Relaxed data stores, even Release store). TWO write rounds on
/// purpose: one round alone cannot tear — the Acquire entry / Release
/// publish pair covers it — the fences earn their keep when a reader
/// holding a stale round-1 version re-checks against round-2 data
/// (cross-checked in scripts/chk_sim_pr10.py, "seqlock fence-less").
fn mini_seqlock_round(weakened: bool) {
    let ver = Arc::new(AtomicU32::new(0));
    let d0 = Arc::new(AtomicU64::new(0));
    let d1 = Arc::new(AtomicU64::new(0));
    let writer = {
        let (ver, d0, d1) = (ver.clone(), d0.clone(), d1.clone());
        chk::thread::spawn(move || {
            for round in 0u32..2 {
                let val = 7 + u64::from(round);
                ver.store(2 * round + 1, Ordering::Relaxed); // ord: Relaxed — odd claim (single writer)
                if !weakened {
                    // ord: Release fence — publishes the odd claim before the data
                    fence(Ordering::Release);
                }
                d0.store(val, Ordering::Relaxed); // ord: Relaxed — certified by the version protocol
                d1.store(val, Ordering::Relaxed); // ord: Relaxed — certified by the version protocol
                ver.store(2 * round + 2, Ordering::Release); // ord: Release — even store publishes
            }
        })
    };
    let v = ver.load(Ordering::Acquire); // ord: Acquire — seqlock read entry
    if v != 0 && v % 2 == 0 {
        let a = d0.load(Ordering::Relaxed); // ord: Relaxed — re-check certifies
        let b = d1.load(Ordering::Relaxed); // ord: Relaxed — re-check certifies
        if !weakened {
            // ord: Acquire fence — orders the data loads before the re-check
            fence(Ordering::Acquire);
        }
        // ord: Relaxed — the fence pair makes this re-check sound.
        if ver.load(Ordering::Relaxed) == v {
            assert!(a == b, "torn seqlock read: {a} vs {b}");
        }
    }
    writer.join().unwrap();
}

#[test]
fn seqlock_with_fences_never_tears() {
    chk::model(|| mini_seqlock_round(false));
}

/// Sensitivity proof: strip the fence pair and the same protocol tears
/// — the explorer must find the schedule where a reader validates
/// round-2 data against a stale round-1 version.
#[test]
fn seqlock_without_fences_fails() {
    let report = chk::model_expect_failure(|| mini_seqlock_round(true));
    assert!(report.contains("torn seqlock read"), "unexpected report: {report}");
}

/// Direct demonstration of the weakened seqlock failing: run manually
/// (`cargo test --features chk -- --ignored demo_`) to see the op trace
/// the explorer reports for the torn read.
#[test]
#[ignore = "sensitivity demo: fails by design to print the torn-read trace"]
fn demo_weakened_seqlock_trace() {
    chk::model(|| mini_seqlock_round(true));
}

// ---------------------------------------------------------------------------
// Model 3 — BoundedQueue: close racing pop_batch
// ---------------------------------------------------------------------------

/// A producer pushes two items and closes while the consumer drains
/// with `pop_batch`. Close must wake the consumer and never lose items:
/// every explored interleaving drains exactly `[1, 2]` before `Closed`.
#[test]
fn queue_close_race_loses_nothing() {
    chk::model(|| {
        let q = ama::exec::BoundedQueue::new(2);
        let producer = {
            let q = q.clone();
            chk::thread::spawn(move || {
                q.push(1u32).unwrap();
                q.push(2u32).unwrap();
                q.close();
            })
        };
        let mut got = Vec::new();
        loop {
            match q.pop_batch(8, Duration::from_millis(1)) {
                Ok(batch) => got.extend(batch),
                Err(ama::exec::QueueError::Timeout) => continue, // producer not done yet
                Err(ama::exec::QueueError::Closed) => break,
                Err(e) => panic!("unexpected queue error: {e:?}"),
            }
        }
        producer.join().unwrap();
        assert_eq!(got, vec![1, 2], "close dropped or reordered queued items");
    });
}

// ---------------------------------------------------------------------------
// Model 4 — CircuitBreaker: open→half-open single-trial admission
// ---------------------------------------------------------------------------

/// Once the breaker opens and the cooldown expires, two racing callers
/// must resolve to exactly one half-open probe (the other is denied):
/// the probe slot is the mutual exclusion the downstream endpoint's
/// recovery depends on. The probe's success must close the breaker.
#[test]
fn breaker_half_open_admits_exactly_one_probe() {
    chk::model(|| {
        use ama::gateway::breaker::{Admission, BreakerConfig, BreakerState, CircuitBreaker};
        let br = Arc::new(CircuitBreaker::new(BreakerConfig {
            failure_threshold: 1,
            cooldown: Duration::ZERO,
        }));
        br.record_failure(); // trips closed→open; cooldown 0 arms the trial
        let t = {
            let br = br.clone();
            chk::thread::spawn(move || br.try_admit())
        };
        let here = br.try_admit();
        let there = t.join().unwrap();
        let probes = [&here, &there]
            .iter()
            .filter(|a| matches!(a, Admission::Probe(_)))
            .count();
        let denials = [&here, &there]
            .iter()
            .filter(|a| matches!(a, Admission::Denied { .. }))
            .count();
        assert_eq!((probes, denials), (1, 1), "probe slot not exclusive: {here:?} / {there:?}");
        br.record_success();
        assert_eq!(br.state(), BreakerState::Closed);
    });
}

// ---------------------------------------------------------------------------
// Model 5 — CoalesceMap: leader-crash drop-guard publication
// ---------------------------------------------------------------------------

/// A coalescing leader that aborts without completing (panic, early
/// return) must still publish through its drop-guard: a follower parked
/// on the slot wakes with the leader-aborted error instead of hanging
/// until its deadline, and the key is retired from the table.
#[test]
fn coalescer_leader_crash_publishes_to_followers() {
    chk::model(|| {
        use ama::gateway::coalesce::{Claim, CoalesceMap};
        let map = Arc::new(CoalesceMap::new());
        let leader = match map.claim(7) {
            Claim::Leader(l) => l,
            Claim::Follower(_) => unreachable!("first claim must lead"),
        };
        let follower = {
            let map = map.clone();
            chk::thread::spawn(move || match map.claim(7) {
                Claim::Follower(f) => {
                    f.wait_deadline(Instant::now() + Duration::from_secs(5))
                }
                // The leader's drop already retired the key: this caller
                // is a fresh leader; its own drop-guard publishes.
                Claim::Leader(l) => {
                    drop(l);
                    None
                }
            })
        };
        drop(leader); // crash before completing
        if let Some(outcome) = follower.join().unwrap() {
            let err = outcome.expect_err("aborted leader cannot publish a success");
            assert_eq!(err.code, ama::analysis::ErrorCode::Unavailable);
        }
        assert!(map.is_empty(), "crashed leader leaked its key");
    });
}

// ---------------------------------------------------------------------------
// PR 9 satellites — WriteBuf watermark counters, completion mailbox
// ---------------------------------------------------------------------------

/// The event loop's backpressure accounting (`loops.rs`): the loop
/// thread owns the `WriteBuf` and its `paused` bool exclusively, and
/// publishes only the `pauses` counter (Relaxed) plus a stop flag
/// (Release). A monitor racing the loop must see a monotone prefix
/// (never more pauses than transitions so far), and the join edge must
/// make the final count exact.
#[test]
fn writebuf_watermark_pause_counter_is_exact_after_join() {
    chk::model(|| {
        let pauses = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let lp = {
            let (pauses, stop) = (pauses.clone(), stop.clone());
            chk::thread::spawn(move || {
                let mut wb = ama::net::WriteBuf::new();
                let mut paused = false;
                let chunk = vec![0u8; ama::net::WRITE_HIGH_WATER + 1];
                for _ in 0..2 {
                    wb.push(&chunk);
                    if !paused && wb.over_high_water() {
                        paused = true;
                        pauses.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                    }
                    let n = wb.len();
                    wb.advance(n); // socket drained: resume
                    if paused && wb.below_low_water() {
                        paused = false;
                    }
                }
                assert!(!paused, "drained buffer must resume reads");
                stop.store(true, Ordering::Release); // ord: Release — stop flag
            })
        };
        // Racing monitor read: a prefix of the final count, never more.
        let seen = pauses.load(Ordering::Relaxed); // ord: Relaxed — stats
        assert!(seen <= 2, "counter overshot: {seen}");
        lp.join().unwrap();
        assert!(stop.load(Ordering::Acquire)); // ord: Acquire — pairs with the Release store
        // ord: Relaxed — the join edge orders this read after the loop.
        assert_eq!(pauses.load(Ordering::Relaxed), 2, "pause transitions lost");
    });
}

/// The completion-mailbox wakeup handshake (`loops.rs`): an offloaded
/// worker pushes its payload into the mailbox *then* writes the waker
/// (modeled as a Release flag — the pipe write the poller observes).
/// A loop thread that consumes the waker byte (Acquire swap) is
/// guaranteed to see the pushed payload on its next drain — no request
/// can be stranded in the mailbox with the loop parked.
#[test]
fn completion_mailbox_wake_implies_visible_payload() {
    chk::model(|| {
        let mailbox: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let wake = Arc::new(AtomicBool::new(false));
        let worker = {
            let (mailbox, wake) = (mailbox.clone(), wake.clone());
            chk::thread::spawn(move || {
                mailbox.lock().unwrap().push(17); // CompletionSender::send: push…
                wake.store(true, Ordering::Release); // ord: Release — …then wake (publishes the push)
            })
        };
        let mut got = std::mem::take(&mut *mailbox.lock().unwrap());
        if got.is_empty() {
            // About to park: the poller consumes the waker byte first.
            // ord: AcqRel — the acquire half syncs with the worker's
            // Release, so the drain below must see the push.
            if wake.swap(false, Ordering::AcqRel) {
                got = std::mem::take(&mut *mailbox.lock().unwrap());
                assert_eq!(got, vec![17], "woken loop found an empty mailbox (lost completion)");
            }
        }
        worker.join().unwrap();
        let rest = std::mem::take(&mut *mailbox.lock().unwrap());
        assert_eq!(got.len() + rest.len(), 1, "completion lost or duplicated");
    });
}

/// Sensitivity proof for the mailbox protocol: invert the order (wake
/// first, push after — the bug the `// ord:` comment in `loops.rs`
/// guards against) and the loop can consume the wake, find the mailbox
/// empty, and park with the payload stranded. The explorer must find it.
#[test]
fn completion_mailbox_wake_before_push_fails() {
    let report = chk::model_expect_failure(|| {
        let mailbox: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
        let wake = Arc::new(AtomicBool::new(false));
        let worker = {
            let (mailbox, wake) = (mailbox.clone(), wake.clone());
            chk::thread::spawn(move || {
                wake.store(true, Ordering::Release); // ord: Release — deliberately wrong order
                mailbox.lock().unwrap().push(17); // bug: push lands after the wake
            })
        };
        let got = std::mem::take(&mut *mailbox.lock().unwrap());
        // ord: AcqRel — consume the waker byte, then drain.
        if got.is_empty() && wake.swap(false, Ordering::AcqRel) {
            let drained = std::mem::take(&mut *mailbox.lock().unwrap());
            assert!(!drained.is_empty(), "woken loop found an empty mailbox (lost completion)");
        }
        worker.join().unwrap();
    });
    assert!(report.contains("empty mailbox"), "unexpected report: {report}");
}

// ---------------------------------------------------------------------------
// Audit regressions — orderings the `// ord:` sweep downgraded/kept
// ---------------------------------------------------------------------------

/// The stop-flag pattern every server/gateway/metrics thread now uses
/// (Release store, Acquire poll — downgraded from SeqCst in the PR 10
/// audit): the flag alone is a full handshake for everything the
/// stopping thread wrote before it.
#[test]
fn stop_flag_release_acquire_handshake() {
    chk::model(|| {
        let progress = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let t = {
            let (progress, stop) = (progress.clone(), stop.clone());
            chk::thread::spawn(move || {
                progress.store(9, Ordering::Relaxed); // ord: Relaxed — published by stop below
                stop.store(true, Ordering::Release); // ord: Release — stop flag
            })
        };
        // ord: Acquire — pairs with the Release store above.
        if stop.load(Ordering::Acquire) {
            assert_eq!(progress.load(Ordering::Relaxed), 9); // ord: Relaxed — ordered by stop
        }
        t.join().unwrap();
    });
}

/// The coordinator's `failed_inits` counter (downgraded SeqCst→Relaxed
/// in the audit): the RMW's atomicity — not its ordering — is what
/// guarantees exactly one of N workers observes the final count and
/// reports the all-failed condition.
#[test]
fn relaxed_counter_rmw_still_counts_exactly() {
    chk::model(|| {
        let fails = Arc::new(ama::chk::sync::AtomicUsize::new(0));
        let workers = 2usize;
        let last = Arc::new(AtomicU32::new(0));
        let ts: Vec<_> = (0..workers)
            .map(|_| {
                let (fails, last) = (fails.clone(), last.clone());
                chk::thread::spawn(move || {
                    // ord: Relaxed — pure counter; atomicity does the work
                    if fails.fetch_add(1, Ordering::Relaxed) + 1 == 2 {
                        last.fetch_add(1, Ordering::Relaxed); // ord: Relaxed — stats
                    }
                })
            })
            .collect();
        for t in ts {
            t.join().unwrap();
        }
        // ord: Relaxed — join edges order these reads after both workers.
        assert_eq!(fails.load(Ordering::Relaxed), 2, "lost update on Relaxed RMW");
        // ord: Relaxed — same join-edge argument as the line above.
        assert_eq!(last.load(Ordering::Relaxed), 1, "all-failed detection not exclusive");
    });
}
