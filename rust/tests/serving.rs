//! Sustained-load serving-path tests: many real TCP connections driving
//! the coordinator through the server's handler pool, asserting zero
//! lost/reordered replies and exact metrics accounting — the ROADMAP
//! "server load test" item.

use ama::chars::ArabicWord;
use ama::coordinator::{BackendFactory, Coordinator, CoordinatorConfig, SoftwareBackend};
use ama::roots::RootSet;
use ama::server::{Server, ServerConfig};
use ama::stemmer::Stemmer;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

fn roots() -> Arc<RootSet> {
    Arc::new(RootSet::builtin_mini())
}

fn sw_factory(r: Arc<RootSet>) -> BackendFactory {
    Box::new(move |_| Ok(Box::new(SoftwareBackend(Stemmer::with_defaults(r.clone())))))
}

/// 32 connections × 320 words = 10,240 words through real TCP in
/// pipelined bursts. Every reply must echo its word in order, carry the
/// same root the stemmer computes directly, and the server-side request
/// counter must land exactly on the total.
#[test]
fn sustained_load_no_loss_no_reorder_exact_metrics() {
    const CONNS: usize = 32;
    const WORDS_PER_CONN: usize = 320;
    const BURST: usize = 64;

    let r = roots();
    let vocab: Vec<&str> =
        vec!["يدرس", "قال", "سيلعبون", "فتزحزحت", "ظظظ", "يلعب", "درس", "كتب"];
    // expected root (by direct stemming) for each vocab word
    let stemmer = Stemmer::with_defaults(r.clone());
    let expected: HashMap<String, String> = vocab
        .iter()
        .map(|w| {
            let res = stemmer.stem(&ArabicWord::encode(w));
            (w.to_string(), res.root_word().to_string_ar())
        })
        .collect();

    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, max_batch: 128, ..Default::default() },
        sw_factory(r.clone()),
    );
    let server = Arc::new(
        Server::bind_with(
            "127.0.0.1:0",
            coord.handle(),
            ServerConfig { handlers: CONNS, ..Default::default() },
        )
        .unwrap(),
    );
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());

    let clients: Vec<_> = (0..CONNS)
        .map(|id| {
            let vocab: Vec<String> = vocab.iter().map(|s| s.to_string()).collect();
            let expected = expected.clone();
            std::thread::spawn(move || -> u64 {
                let conn = TcpStream::connect(addr).unwrap();
                conn.set_nodelay(true).unwrap();
                conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
                let mut writer = conn.try_clone().unwrap();
                let mut reader = BufReader::new(conn);
                let mut got = 0u64;
                let mut next = id % vocab.len();
                let mut line = String::new();
                for _ in 0..(WORDS_PER_CONN / BURST) {
                    // pipelined burst: BURST lines before any read
                    let mut sent = Vec::with_capacity(BURST);
                    let mut burst = String::new();
                    for _ in 0..BURST {
                        burst.push_str(&vocab[next]);
                        burst.push('\n');
                        sent.push(vocab[next].clone());
                        next = (next + 1) % vocab.len();
                    }
                    writer.write_all(burst.as_bytes()).unwrap();
                    for w in &sent {
                        line.clear();
                        assert!(
                            reader.read_line(&mut line).unwrap() > 0,
                            "conn {id}: server closed mid-burst"
                        );
                        let mut fields = line.trim_end().split('\t');
                        let echoed = fields.next().unwrap();
                        let root = fields.next().unwrap();
                        assert_eq!(echoed, w, "conn {id}: reply out of order");
                        assert_eq!(&expected[w], root, "conn {id}: wrong root for {w}");
                        got += 1;
                    }
                }
                writer.write_all(b"\n").unwrap(); // close
                got
            })
        })
        .collect();

    let total: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
    assert_eq!(total, (CONNS * WORDS_PER_CONN) as u64, "lost replies");

    // Exact accounting: every word stemmed exactly once, no errors.
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.requests, (CONNS * WORDS_PER_CONN) as u64, "snapshot().requests inexact");
    assert_eq!(snap.words, (CONNS * WORDS_PER_CONN) as u64);
    assert_eq!(snap.errors, 0);
    assert!(snap.p99_us > 0, "latency histogram never populated");
    // Pipelined bursts must actually aggregate at the connection level:
    // far fewer backend batches than words.
    assert!(
        snap.batches < snap.words / 4,
        "no connection-level batching: {} batches for {} words",
        snap.batches,
        snap.words
    );

    assert_eq!(server.stats.accepted(), CONNS as u64);
    server.stop();
    serve_thread.join().unwrap().unwrap();
    assert_eq!(server.stats.active(), 0, "handlers drained");
    assert_eq!(server.stats.completed(), CONNS as u64);
    coord.shutdown();
}

/// The interactive protocol and the pipelined protocol return identical
/// results, and both match the coordinator's bulk/stream APIs.
#[test]
fn pipelined_and_interactive_agree_with_bulk_apis() {
    let r = roots();
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, max_batch: 32, ..Default::default() },
        sw_factory(r.clone()),
    );
    let handle = coord.handle();
    let vocab = ["يدرس", "قال", "سيلعبون", "فتزحزحت", "ظظظ"];
    let sent: Vec<String> =
        vocab.iter().cycle().take(60).map(|s| s.to_string()).collect();
    let words: Vec<ArabicWord> = sent.iter().map(|s| ArabicWord::encode(s)).collect();

    // API-level order preservation (acceptance: bulk == stream)
    let bulk = handle.stem_bulk(&words).unwrap();
    let stream = handle.stem_stream(&words).unwrap();
    assert_eq!(bulk, stream);

    let server = Arc::new(Server::bind("127.0.0.1:0", coord.handle()).unwrap());
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());

    // Interactive: one line at a time.
    let conn = TcpStream::connect(addr).unwrap();
    conn.set_nodelay(true).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut interactive = Vec::new();
    let mut line = String::new();
    for w in &sent {
        writeln!(writer, "{w}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        interactive.push(line.trim_end().to_string());
    }
    writer.write_all(b"\n").unwrap();

    // Pipelined: the whole burst at once.
    let conn = TcpStream::connect(addr).unwrap();
    conn.set_nodelay(true).unwrap();
    let mut writer = conn.try_clone().unwrap();
    let mut reader = BufReader::new(conn);
    let mut burst = String::new();
    for w in &sent {
        burst.push_str(w);
        burst.push('\n');
    }
    writer.write_all(burst.as_bytes()).unwrap();
    let mut pipelined = Vec::new();
    for _ in &sent {
        line.clear();
        reader.read_line(&mut line).unwrap();
        pipelined.push(line.trim_end().to_string());
    }
    writer.write_all(b"\n").unwrap();

    assert_eq!(interactive, pipelined, "the two protocol modes diverged");
    // And the wire replies carry the same roots as the direct API.
    for (reply, res) in pipelined.iter().zip(&bulk) {
        let root = reply.split('\t').nth(1).unwrap();
        assert_eq!(root, res.root_word().to_string_ar(), "{reply}");
    }

    server.stop();
    serve_thread.join().unwrap().unwrap();
    coord.shutdown();
}

/// C10K readiness smoke (PR 9): 1024 mostly-idle keepalive connections
/// against the event-loop ingest. 95% of the fleet parks after a warmup
/// round-trip while the remainder bursts pipelined load; at the end every
/// parked connection must still answer — no loss, no reorder, no parked
/// connection dropped. Connection count degrades gracefully if the
/// RLIMIT_NOFILE budget cannot cover 1024 sockets.
#[test]
fn c10k_mostly_idle_no_loss_no_reorder() {
    const WANT_CONNS: usize = 1024;
    let r = roots();
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, max_batch: 128, ..Default::default() },
        sw_factory(r.clone()),
    );
    let server = Arc::new(
        Server::bind_with(
            "127.0.0.1:0",
            coord.handle(),
            // handlers stays small on purpose: the event-loop ingest must
            // carry the fleet; only a (non-default) blocking fallback
            // would be gated by it.
            ServerConfig { handlers: 8, ..Default::default() },
        )
        .unwrap(),
    );
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());

    let words: Vec<String> =
        ["يدرس", "قال", "سيلعبون", "فتزحزحت"].iter().map(|s| s.to_string()).collect();
    let outcome = ama::bench::run_mostly_idle_load(
        addr,
        WANT_CONNS,
        0.95,
        Duration::from_millis(750),
        32,
        &words,
    );
    assert_eq!(outcome.errors, 0, "client errors (a parked connection was dropped?)");
    assert_eq!(outcome.reorders, 0, "reordered replies");
    assert!(outcome.words > 0, "no traffic flowed");
    assert!(
        outcome.conns >= WANT_CONNS / 2,
        "fd budget collapsed the fleet: only {} conns",
        outcome.conns
    );
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.errors, 0, "server-side errors under mostly-idle load");
    assert!(
        server.stats.accepted() >= outcome.conns as u64,
        "accepted {} < fleet size {}",
        server.stats.accepted(),
        outcome.conns
    );

    server.stop();
    serve_thread.join().unwrap().unwrap();
    coord.shutdown();
}

/// The in-crate load generator drives a real server end to end (a
/// seconds-long smoke of what `ama loadtest` does).
#[test]
fn load_generator_smoke() {
    let r = roots();
    let coord = Coordinator::start(
        CoordinatorConfig { workers: 2, max_batch: 128, ..Default::default() },
        sw_factory(r.clone()),
    );
    let server = Arc::new(
        Server::bind_with(
            "127.0.0.1:0",
            coord.handle(),
            ServerConfig { handlers: 8, ..Default::default() },
        )
        .unwrap(),
    );
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());

    let words: Vec<String> =
        ["يدرس", "قال", "سيلعبون", "فتزحزحت"].iter().map(|s| s.to_string()).collect();
    let outcome =
        ama::bench::run_tcp_load(addr, 8, Duration::from_millis(500), 32, &words);
    assert_eq!(outcome.errors, 0, "client errors");
    assert_eq!(outcome.reorders, 0, "reordered replies");
    assert!(outcome.words > 0, "no traffic flowed");
    assert!(outcome.rtt_p50_us > 0);
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.requests, outcome.words, "server/client word counts diverge");

    server.stop();
    serve_thread.join().unwrap().unwrap();
    coord.shutdown();
}
