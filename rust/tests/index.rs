//! PR 8 corpus-engine acceptance: index+search round-trip over a
//! synthetic corpus (every indexed root retrievable, positions exact),
//! AMAIDX01 snapshot byte-stability, empty/oversized/non-Arabic edge
//! cases, and the AMA/1 `index`/`search` ops over real TCP — direct to a
//! replica and forwarded through the gateway's single-home pool.

use ama::analysis::{Algorithm, AnalyzeOptions, AnalyzerRegistry, ErrorCode};
use ama::chars::PackedWord;
use ama::client::{Client, ClientError};
use ama::coordinator::{Coordinator, CoordinatorConfig};
use ama::corpus::{self, CorpusConfig};
use ama::gateway::fleet::{Fleet, FleetConfig};
use ama::gateway::pool::PoolConfig;
use ama::gateway::{Gateway, GatewayConfig};
use ama::index::pipeline::{self, AnalyzeVia, DocUnit, PipelineConfig};
use ama::index::{
    self, corpus_units, index_from_run, root_key, snapshot, CorpusIndex, IndexService,
    IndexServiceConfig,
};
use ama::protocol::{Envelope, Reply};
use ama::rng::SplitMix64;
use ama::roots::RootSet;
use ama::server::Server;
use ama::stemmer::StemmerConfig;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Duration;

fn voting_opts() -> AnalyzeOptions {
    AnalyzeOptions::with_algorithm(Algorithm::Voting)
}

/// Analyze `words` through an in-process registry and add them as one
/// document; returns the doc id.
fn add_doc(idx: &mut CorpusIndex, reg: &AnalyzerRegistry, name: &str, words: &[&str]) -> u32 {
    let packed: Vec<PackedWord> = words.iter().map(|w| PackedWord::encode(w)).collect();
    let surfaces: Vec<String> = words.iter().map(|w| w.to_string()).collect();
    let analyses = reg.analyze_batch_packed(&packed, &voting_opts());
    idx.add_doc(name, &packed, &surfaces, &analyses)
}

/// Build a pipeline-produced index over a small calibrated corpus, and
/// return it together with the pipeline run for cross-checking.
fn pipeline_index(words: usize, seed: u64) -> (CorpusIndex, pipeline::PipelineRun) {
    let roots = Arc::new(RootSet::builtin_mini());
    let corpus = corpus::generate(&roots, &CorpusConfig::small(words, seed));
    let units = corpus_units(&corpus, 50);
    let reg = Arc::new(AnalyzerRegistry::new(roots));
    let cfg = PipelineConfig { workers: 2, opts: voting_opts(), ..Default::default() };
    let stages = pipeline::build_stages(AnalyzeVia::Registry(reg), &cfg, None);
    let run = pipeline::run(stages, units, &cfg);
    (index_from_run(&run), run)
}

/// Acceptance pin: every root the pipeline indexed is retrievable by
/// search, with exact positions and exact per-document scores.
#[test]
fn pipeline_index_search_round_trip() {
    let (idx, run) = pipeline_index(400, 11);
    let stats = idx.stats();
    assert_eq!(stats.docs, run.docs.len());
    assert_eq!(stats.words_seen, run.words_total);
    assert!(stats.docs >= 2, "corpus should shard into multiple docs");
    assert!(stats.postings > 0, "calibrated corpus must index roots");

    // Ground truth straight from the pipeline output: key → doc → tf,
    // and key → set of (doc, pos).
    let mut tf: HashMap<u128, HashMap<u32, u64>> = HashMap::new();
    let mut occurrences: HashMap<u128, HashSet<(u32, u32)>> = HashMap::new();
    for (doc, unit) in run.docs.iter().enumerate() {
        let doc = doc as u32;
        assert_eq!(unit.id, doc, "collector must hand docs back in dense id order");
        for (pos, a) in unit.analyses.iter().enumerate() {
            if let Some(key) = root_key(&a.result) {
                *tf.entry(key).or_default().entry(doc).or_insert(0) += 1;
                occurrences.entry(key).or_default().insert((doc, pos as u32));
            }
        }
    }
    assert_eq!(tf.len(), stats.distinct_roots, "index key set == pipeline key set");

    for (&key, docs) in &tf {
        // Postings carry exact (doc, pos) pairs.
        let postings = idx.postings(key).expect("indexed key has postings");
        let got: HashSet<(u32, u32)> = postings.iter().map(|p| (p.doc, p.pos)).collect();
        assert_eq!(got, occurrences[&key], "positions exact for key {key:#x}");

        // Single-root search finds exactly the docs containing it,
        // scored by term frequency (desc, doc id asc on ties).
        let hits = idx.search(&[key], usize::MAX);
        let hit_docs: HashSet<u32> = hits.iter().map(|h| h.doc).collect();
        assert_eq!(hit_docs, docs.keys().copied().collect::<HashSet<u32>>());
        for h in &hits {
            assert_eq!(h.score, docs[&h.doc], "score is the root's tf in the doc");
            assert_eq!(h.matched_roots, 1);
            assert!(!h.contexts.is_empty(), "hits carry surface-form contexts");
            for c in &h.contexts {
                assert!(occurrences[&key].contains(&(h.doc, c.pos)));
                assert!(!c.form.is_empty());
            }
        }
        for w in hits.windows(2) {
            assert!(
                w[0].score > w[1].score || (w[0].score == w[1].score && w[0].doc < w[1].doc),
                "ranking must be score desc then doc asc"
            );
        }
    }
}

/// Multi-root queries intersect strictly: a doc matches only when every
/// distinct query root occurs in it.
#[test]
fn search_intersects_roots_strictly() {
    let roots = Arc::new(RootSet::builtin_mini());
    let reg = AnalyzerRegistry::new(roots);
    let mut idx = CorpusIndex::new();
    let d0 = add_doc(&mut idx, &reg, "both", &["يدرس", "قال", "الدرس"]);
    let d1 = add_doc(&mut idx, &reg, "study-only", &["مدروس", "دارس"]);
    let d2 = add_doc(&mut idx, &reg, "play-only", &["سيلعبون"]);

    let key = |w: &str| {
        let a = reg.analyze_batch_packed(&[PackedWord::encode(w)], &voting_opts());
        root_key(&a[0].result).expect("test words must root")
    };
    let (study, say, play) = (key("يدرس"), key("قال"), key("لعب"));
    assert_ne!(study, say);

    // Strict AND: only d0 carries both درس and قول.
    let hits = idx.search(&[study, say], 10);
    assert_eq!(hits.iter().map(|h| h.doc).collect::<Vec<u32>>(), vec![d0]);
    assert_eq!(hits[0].matched_roots, 2);
    assert_eq!(hits[0].score, 3, "2× درس + 1× قول in the doc");

    // Duplicate query roots count once — same result set and scores.
    assert_eq!(idx.search(&[study, say, study], 10).len(), 1);

    // Single root ranks by tf: d0 has two درس occurrences, d1 two.
    let hits = idx.search(&[study], 10);
    assert_eq!(hits.len(), 2);
    assert_eq!(
        hits.iter().map(|h| (h.doc, h.score)).collect::<Vec<(u32, u64)>>(),
        vec![(d0, 2), (d1, 2)],
        "tie on tf=2 breaks toward the lower doc id"
    );

    // No document contains both درس and لعب — strict AND yields nothing.
    assert!(idx.search(&[study, play], 10).is_empty());
    assert!(idx.search(&[study, play, say], 10).is_empty());
    assert!(idx.search(&[play], 10).iter().map(|h| h.doc).eq([d2]));
    assert!(idx.search(&[0xDEAD_BEEF], 10).is_empty(), "unknown key → no hits");
}

/// Snapshot acceptance: encode→decode→encode is byte-identical,
/// save/load round-trips through a file, and corruption is detected.
#[test]
fn snapshot_bytes_are_stable_and_checksummed() {
    let (idx, run) = pipeline_index(300, 7);
    let bytes = snapshot::to_bytes(&idx);
    assert_eq!(&bytes[..8], b"AMAIDX01");

    let decoded = snapshot::from_bytes(&bytes).expect("own snapshot must decode");
    assert_eq!(snapshot::to_bytes(&decoded), bytes, "re-encode is byte-identical");
    let (a, b) = (idx.stats(), decoded.stats());
    assert_eq!(a.docs, b.docs);
    assert_eq!(a.distinct_roots, b.distinct_roots);
    assert_eq!(a.postings, b.postings);
    assert_eq!(a.forms, b.forms);
    assert_eq!(a.words_seen, b.words_seen);
    assert_eq!(a.words_indexed, b.words_indexed);

    // Postings survive exactly (delta coding is lossless), checked over
    // every key the pipeline produced.
    for unit in &run.docs {
        for a in &unit.analyses {
            if let Some(key) = root_key(&a.result) {
                assert_eq!(idx.postings(key), decoded.postings(key));
            }
        }
    }
    for doc in 0..a.docs as u32 {
        assert_eq!(idx.doc(doc), decoded.doc(doc));
    }

    // File round-trip under a collision-proof temp path.
    let path = std::env::temp_dir()
        .join(format!("ama-idx-test-{}-{:?}", std::process::id(), std::thread::current().id()));
    snapshot::save(&idx, &path).expect("save");
    let loaded = snapshot::load(&path).expect("load");
    assert_eq!(snapshot::to_bytes(&loaded), bytes);
    std::fs::remove_file(&path).ok();

    // A flipped payload byte must fail the FNV-1a trailer check.
    let mut corrupt = bytes.clone();
    let mid = corrupt.len() / 2;
    corrupt[mid] ^= 0x40;
    assert!(snapshot::from_bytes(&corrupt).is_err(), "corruption must not load");
    // Truncation must error, not panic.
    assert!(snapshot::from_bytes(&bytes[..bytes.len() - 3]).is_err());
    assert!(snapshot::from_bytes(&[]).is_err());
}

/// Empty/oversized/non-Arabic edges: empty index and empty key set
/// return no hits; the segment stage drops non-Arabic tokens and
/// re-bases positions; the shared service enforces its caps with typed
/// errors.
#[test]
fn edge_cases_empty_non_arabic_and_caps() {
    // Empty index / empty query.
    let empty = CorpusIndex::new();
    assert!(empty.search(&[1], 10).is_empty());
    assert!(empty.search(&[], 10).is_empty());
    let s = empty.stats();
    assert_eq!((s.docs, s.postings, s.distinct_roots), (0, 0, 0));
    let empty_bytes = snapshot::to_bytes(&empty);
    let empty_rt = snapshot::from_bytes(&empty_bytes).expect("empty snapshot round-trips");
    assert_eq!(snapshot::to_bytes(&empty_rt), empty_bytes);

    // Non-Arabic text: the tokenize+segment stages drop `hello`/`123`
    // and positions are re-based over the survivors.
    let roots = Arc::new(RootSet::builtin_mini());
    let reg = Arc::new(AnalyzerRegistry::new(roots));
    let cfg = PipelineConfig { workers: 1, opts: voting_opts(), ..Default::default() };
    let stages = pipeline::build_stages(AnalyzeVia::Registry(reg.clone()), &cfg, None);
    let units = vec![
        DocUnit::from_text(0, "mixed", "hello يدرس, world 123 قال!"),
        DocUnit::from_text(1, "ascii-only", "nothing to see here 42"),
    ];
    let run = pipeline::run(stages, units, &cfg);
    let idx = index_from_run(&run);
    assert_eq!(run.docs[0].surfaces, vec!["يدرس", "قال"], "punctuation trimmed, ascii dropped");
    assert!(run.docs[1].surfaces.is_empty(), "all-ascii doc survives as an empty doc");
    assert_eq!(idx.stats().docs, 2);
    let a = reg.analyze_batch_packed(&[PackedWord::encode("يدرس")], &voting_opts());
    let key = root_key(&a[0].result).unwrap();
    let postings = idx.postings(key).unwrap();
    assert_eq!((postings[0].doc, postings[0].pos), (0, 0), "position re-based after segment");

    // Service caps: docs cap, then words cap, both typed UNAVAILABLE.
    let svc = IndexService::new(IndexServiceConfig { max_docs: 1, max_words: 1 << 20 });
    let packed = [PackedWord::encode("قال")];
    let surfaces = ["قال".to_string()];
    let analyses = reg.analyze_batch_packed(&packed, &voting_opts());
    let (doc, posted) = svc.add_doc("first", &packed, &surfaces, &analyses).expect("under cap");
    assert_eq!((doc, posted), (0, 1));
    let err = svc.add_doc("second", &packed, &surfaces, &analyses).unwrap_err();
    assert_eq!(err.code, ErrorCode::Unavailable, "doc cap → UNAVAILABLE, got {err:?}");

    let svc = IndexService::new(IndexServiceConfig { max_docs: 8, max_words: 1 });
    svc.add_doc("fits", &packed, &surfaces, &analyses).expect("exactly at cap");
    let err = svc.add_doc("overflow", &packed, &surfaces, &analyses).unwrap_err();
    assert_eq!(err.code, ErrorCode::Unavailable, "word cap → UNAVAILABLE, got {err:?}");
    assert_eq!(svc.doc_count(), 1);
}

/// AMA/1 wire acceptance: `index` then `search` against a real TCP
/// replica — hits come back with doc ids, names, scores, and contexts;
/// non-Arabic query words are rejected with BAD_WORD; a rootless query
/// returns zero hits.
#[test]
fn ama1_wire_index_then_search() {
    let roots = Arc::new(RootSet::builtin_mini());
    let coord = Coordinator::start_registry(
        CoordinatorConfig { workers: 2, max_batch: 64, ..Default::default() },
        roots,
        StemmerConfig::default(),
    );
    let server = Arc::new(Server::bind("127.0.0.1:0", coord.handle()).unwrap());
    let addr = server.local_addr().unwrap();
    let srv = server.clone();
    let serve_thread = std::thread::spawn(move || srv.serve_forever());

    let mut client = Client::connect(addr).unwrap();
    let opts = voting_opts();
    let (d0, posted0) =
        client.index_once("study", &["يدرس", "قال", "الدرس", "hello"], &opts).unwrap();
    let (d1, posted1) = client.index_once("play", &["سيلعبون", "لاعب"], &opts).unwrap();
    assert_eq!((d0, d1), (0, 1), "replica assigns dense doc ids");
    assert_eq!(posted0, 3, "3 Arabic words rooted; `hello` dropped before analysis");
    assert_eq!(posted1, 2);

    // Root-based retrieval: surface form يدرس and الدرس share درس.
    let hits = client.search_once(&["مدروس"], &opts, None).unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].doc, d0);
    assert_eq!(hits[0].name, "study");
    assert_eq!(hits[0].score, 2, "درس occurs twice in the doc");
    assert_eq!(hits[0].matched, 1);
    assert!(hits[0].contexts.iter().any(|c| c.form == "يدرس"));
    assert!(hits[0].contexts.iter().any(|c| c.form == "الدرس"));

    // Strict AND across both docs' roots matches nothing.
    assert!(client.search_once(&["يدرس", "يلعب"], &opts, None).unwrap().is_empty());
    // لعب retrieves the second doc.
    let hits = client.search_once(&["لعب"], &opts, Some(5)).unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].name, "play");

    // Non-Arabic query word → typed BAD_WORD.
    match client.search_once(&["يدرس", "xyz"], &opts, None) {
        Err(ClientError::Remote(e)) => assert_eq!(e.code, ErrorCode::BadWord),
        other => panic!("expected BAD_WORD, got {other:?}"),
    }
    // Valid Arabic with no recoverable root → empty key set → no hits.
    assert!(client.search_once(&["ظظظ"], &opts, None).unwrap().is_empty());
    // Empty query → BAD_REQUEST.
    match client.search_once(&[], &opts, None) {
        Err(ClientError::Remote(e)) => assert_eq!(e.code, ErrorCode::BadRequest),
        other => panic!("expected BAD_REQUEST, got {other:?}"),
    }

    drop(client);
    server.stop();
    serve_thread.join().unwrap().unwrap();
    coord.shutdown();
}

/// Gateway acceptance: `index`/`search` envelopes forwarded through the
/// pool land on the same single-home replica, so a search finds what a
/// prior index op wrote even with multiple replicas in the ring; replica
/// errors propagate typed.
#[test]
fn gateway_forwards_retrieval_to_single_home() {
    let fleet = Fleet::start(2, FleetConfig::mini());
    let gw = Gateway::new(
        fleet.addrs(),
        GatewayConfig {
            probe_interval: Duration::ZERO,
            request_deadline: Duration::from_secs(2),
            pool: PoolConfig {
                connect_timeout: Duration::from_millis(200),
                ..PoolConfig::default()
            },
            ..GatewayConfig::default()
        },
    );
    let bucket = gw.client_bucket();
    let mut rng = SplitMix64::new(9);
    let opts = voting_opts();

    let req = Envelope::index(41, "doc-a", vec!["يدرس".into(), "قال".into()], opts).to_json();
    match Reply::parse(&gw.serve_line(&req, &bucket, &mut rng)).unwrap() {
        Reply::Indexed { id, name, words, posted, .. } => {
            assert_eq!(id, 41, "front correlation id preserved through the forward");
            assert_eq!(name, "doc-a");
            assert_eq!(words, 2);
            assert_eq!(posted, 2);
        }
        other => panic!("expected indexed ack, got {other:?}"),
    }

    // The search homes on the same replica as the index op, so the doc
    // must be visible regardless of which replicas exist in the ring.
    let req = Envelope::search(42, vec!["الدرس".into()], opts, Some(10)).to_json();
    match Reply::parse(&gw.serve_line(&req, &bucket, &mut rng)).unwrap() {
        Reply::Search { id, hits } => {
            assert_eq!(id, 42);
            assert_eq!(hits.len(), 1, "single-home: search sees the indexed doc");
            assert_eq!(hits[0].name, "doc-a");
            assert_eq!(hits[0].score, 1);
        }
        other => panic!("expected hits, got {other:?}"),
    }

    // Replica-side typed errors propagate through the forward path.
    let req = Envelope::search(43, vec!["abc".into()], opts, None).to_json();
    match Reply::parse(&gw.serve_line(&req, &bucket, &mut rng)).unwrap() {
        Reply::Error { id, error } => {
            assert_eq!(id, 43);
            assert_eq!(error.code, ErrorCode::BadWord);
        }
        other => panic!("expected BAD_WORD error, got {other:?}"),
    }

    fleet.shutdown();
}

/// Pipeline accuracy harness runs end to end and lands in a sane band —
/// the calibrated corpus is built from the mini dictionary, so the
/// voting pipeline should recover the large majority of gold roots.
#[test]
fn accuracy_harness_reports_against_paper_band() {
    let roots = Arc::new(RootSet::builtin_mini());
    let corpus = corpus::generate(&roots, &CorpusConfig::small(300, 3));
    let reg = Arc::new(AnalyzerRegistry::new(roots.clone()));
    let cfg = PipelineConfig { workers: 2, opts: voting_opts(), ..Default::default() };
    let (base, rerank) =
        index::accuracy_harness(AnalyzeVia::Registry(reg), &roots, &corpus, &cfg, 64);
    assert_eq!(base.stemmer, "pipeline-voting");
    assert_eq!(rerank.stemmer, "pipeline-voting+rerank");
    assert_eq!(base.words_total, corpus.tokens.len());
    assert_eq!(rerank.words_total, base.words_total);
    assert!(
        base.root_accuracy() > 0.3,
        "voting pipeline should recover a meaningful share of gold roots, got {:.3}",
        base.root_accuracy()
    );
    assert!(
        rerank.root_accuracy() >= base.root_accuracy() - 0.10,
        "re-rank must not collapse accuracy: base {:.3} vs rerank {:.3}",
        base.root_accuracy(),
        rerank.root_accuracy()
    );
}
