//! Property-based tests (hand-rolled generators over splitmix64 — the
//! offline image has no proptest). Each property runs hundreds of random
//! cases with a deterministic seed; failures print the seed for replay.

use ama::analysis::{Algorithm, AnalyzeOptions, Analyzer, AnalyzerRegistry};
use ama::chars::{self, ArabicWord, PackedWord};
use ama::coordinator::{BackendFactory, Coordinator, CoordinatorConfig, SoftwareBackend};
use ama::corpus::{self, CorpusConfig};
use ama::exec::BoundedQueue;
use ama::hw::{DatapathConfig, NonPipelinedProcessor, PipelinedProcessor, Processor};
use ama::protocol::{Envelope, Reply, WireResult};
use ama::rng::SplitMix64;
use ama::roots::RootSet;
use ama::stemmer::{MatchKind, Stemmer, StemmerConfig};
use std::path::Path;
use std::sync::Arc;

const LETTERS: [u16; 36] = {
    let mut out = [0u16; 36];
    let mut i = 0;
    let mut c = 0x0621u16;
    while c <= 0x063A {
        out[i] = c;
        i += 1;
        c += 1;
    }
    let mut c = 0x0641u16;
    while c <= 0x064A {
        out[i] = c;
        i += 1;
        c += 1;
    }
    out
};

fn roots() -> Arc<RootSet> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("data");
    if dir.join("roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(&dir).unwrap())
    } else {
        Arc::new(RootSet::builtin_mini())
    }
}

fn random_word(rng: &mut SplitMix64) -> ArabicWord {
    let n = rng.index(chars::MAX_WORD + 1);
    let codes: Vec<u16> = (0..n).map(|_| *rng.choose(&LETTERS)).collect();
    ArabicWord::from_codes(&codes)
}

/// Stemmer invariant: whatever is returned is structurally consistent —
/// the root really is the claimed candidate window (possibly transformed),
/// it is in the dictionary, and the cut is a valid prefix run.
#[test]
fn prop_stem_result_structurally_sound() {
    let r = roots();
    let sw = Stemmer::with_defaults(r.clone());
    let mut rng = SplitMix64::new(0x9101);
    for case in 0..3000 {
        let w = random_word(&mut rng);
        let res = sw.stem(&w);
        let p = res.cut as usize;
        match res.kind {
            MatchKind::None => assert_eq!(res.root, [0; 4], "case {case}"),
            MatchKind::Tri => {
                let stem = [w.chars[p], w.chars[p + 1], w.chars[p + 2]];
                assert_eq!(&res.root[..3], &stem, "case {case}: root != window");
                assert!(r.tri.contains(&stem), "case {case}: not in dict");
            }
            MatchKind::Quad => {
                let stem = [w.chars[p], w.chars[p + 1], w.chars[p + 2], w.chars[p + 3]];
                assert_eq!(res.root, stem, "case {case}");
                assert!(r.quad.contains(&stem), "case {case}");
            }
            MatchKind::RmInfixTri => {
                let red = [w.chars[p], w.chars[p + 2], w.chars[p + 3]];
                assert_eq!(&res.root[..3], &red, "case {case}");
                assert!(chars::is_infix_letter(w.chars[p + 1]), "case {case}");
                assert!(r.tri.contains(&red), "case {case}");
            }
            MatchKind::RmInfixBi => {
                let red = [w.chars[p], w.chars[p + 2]];
                assert_eq!(&res.root[..2], &red, "case {case}");
                assert!(r.bi.contains(&red), "case {case}");
            }
            MatchKind::Restored => {
                assert_eq!(w.chars[p + 1], chars::ALEF, "case {case}");
                let restored = [w.chars[p], chars::WAW, w.chars[p + 2]];
                assert_eq!(&res.root[..3], &restored, "case {case}");
                assert!(r.tri.contains(&restored), "case {case}");
            }
        }
        // prefix run validity
        if res.kind != MatchKind::None {
            assert!(w.chars[..p].iter().all(|&c| chars::is_prefix_letter(c)), "case {case}");
        }
    }
}

/// PR 1 acceptance property: the optimized table-driven `stem` is
/// bit-for-bit equal to the retained scalar `stem_reference` — `root`,
/// `kind` and `cut` all match — on 10k randomly inflected words drawn
/// from the dictionary through the paper's own morphological patterns,
/// in both infix configs. This pins
/// the MatchKind priority (tri > quad > rm-infix-tri > rm-infix-bi >
/// restored) and the smallest-cut rule across realistic surface forms.
#[test]
fn prop_optimized_stem_matches_reference() {
    let r = roots();
    let with = Stemmer::with_defaults(r.clone());
    let without = Stemmer::new(r.clone(), StemmerConfig { infix_processing: false });
    let mut rng = SplitMix64::new(0x0917_0001);
    let classes =
        [corpus::FormClass::Direct, corpus::FormClass::Infix, corpus::FormClass::Unstemmable];

    let mut lexicon: Vec<[u16; 4]> = Vec::new();
    for t in r.tri_rows() {
        lexicon.push([t[0], t[1], t[2], 0]);
    }
    for q in r.quad_rows() {
        lexicon.push(*q);
    }
    for b in r.bi_rows() {
        lexicon.push([b[0], b[1], 0, 0]);
    }

    let mut kinds_seen = std::collections::HashSet::new();
    for case in 0..10_000 {
        let gold = *rng.choose(&lexicon);
        let class = *rng.choose(&classes);
        let word = corpus::inflect(&gold, class, &mut rng);
        let a = with.stem(&word);
        let b = with.stem_reference(&word);
        assert_eq!(a, b, "case {case} (with-infix): {word:?}");
        kinds_seen.insert(a.kind);
        let a = without.stem(&word);
        let b = without.stem_reference(&word);
        assert_eq!(a, b, "case {case} (no-infix): {word:?}");
    }
    // the corpus must actually have exercised every extraction algorithm
    for k in [
        MatchKind::None,
        MatchKind::Tri,
        MatchKind::Quad,
        MatchKind::RmInfixTri,
        MatchKind::RmInfixBi,
        MatchKind::Restored,
    ] {
        assert!(kinds_seen.contains(&k), "inflected corpus never produced {k:?}");
    }
}

/// PR 4 acceptance property, part 1: `PackedWord` round-trips exactly —
/// `pack(unpack(p)) == p` and `unpack(pack(w)) == w` — over every
/// dictionary root and 10k randomly inflected corpus words, and the
/// direct string encoder agrees with encode-then-pack.
#[test]
fn prop_packed_roundtrip_dictionary_and_inflected() {
    let r = roots();
    let mut all_words: Vec<ArabicWord> = Vec::new();
    for t in r.tri_rows() {
        all_words.push(ArabicWord::from_codes(t));
    }
    for q in r.quad_rows() {
        all_words.push(ArabicWord::from_codes(q));
    }
    for b in r.bi_rows() {
        all_words.push(ArabicWord::from_codes(b));
    }
    let mut rng = SplitMix64::new(0x0917_0004);
    let classes =
        [corpus::FormClass::Direct, corpus::FormClass::Infix, corpus::FormClass::Unstemmable];
    let lexicon: Vec<[u16; 4]> = all_words
        .iter()
        .map(|w| {
            let mut g = [0u16; 4];
            g[..w.len.min(4)].copy_from_slice(&w.chars[..w.len.min(4)]);
            g
        })
        .collect();
    for _ in 0..10_000 {
        let gold = *rng.choose(&lexicon);
        let class = *rng.choose(&classes);
        all_words.push(corpus::inflect(&gold, class, &mut rng));
    }
    for (case, w) in all_words.iter().enumerate() {
        let p = PackedWord::pack(w);
        assert_eq!(p.unpack(), *w, "case {case}: unpack(pack(w)) != w for {w:?}");
        assert_eq!(PackedWord::pack(&p.unpack()), p, "case {case}: pack not canonical");
        assert_eq!(p.len(), w.len, "case {case}");
        assert_eq!(p.to_indices(), w.to_indices(), "case {case}");
        let s = w.to_string_ar();
        assert_eq!(PackedWord::encode(&s), p, "case {case}: string encoder diverges");
    }
}

/// PR 4 acceptance property, part 2: the packed kernel is bit-identical
/// to both the array kernel and the scalar reference —
/// `stem_packed == stem == stem_reference` on root, kind, and cut — over
/// 10k inflected corpus words in both infix configs, with the batch form
/// agreeing word-for-word.
#[test]
fn prop_packed_kernel_matches_stem_and_reference() {
    let r = roots();
    let with = Stemmer::with_defaults(r.clone());
    let without = Stemmer::new(r.clone(), StemmerConfig { infix_processing: false });
    let mut rng = SplitMix64::new(0x0917_0005);
    let classes =
        [corpus::FormClass::Direct, corpus::FormClass::Infix, corpus::FormClass::Unstemmable];

    let mut lexicon: Vec<[u16; 4]> = Vec::new();
    for t in r.tri_rows() {
        lexicon.push([t[0], t[1], t[2], 0]);
    }
    for q in r.quad_rows() {
        lexicon.push(*q);
    }
    for b in r.bi_rows() {
        lexicon.push([b[0], b[1], 0, 0]);
    }

    let mut words: Vec<ArabicWord> = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        let gold = *rng.choose(&lexicon);
        let class = *rng.choose(&classes);
        words.push(corpus::inflect(&gold, class, &mut rng));
    }
    let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();
    for (stemmer, label) in [(&with, "with-infix"), (&without, "no-infix")] {
        for (case, (w, &p)) in words.iter().zip(&packed).enumerate() {
            let got = stemmer.stem_packed(p);
            assert_eq!(got, stemmer.stem(w), "case {case} ({label}): packed != fused {w:?}");
            assert_eq!(
                got,
                stemmer.stem_reference(w),
                "case {case} ({label}): packed != reference {w:?}"
            );
        }
        assert_eq!(
            stemmer.stem_batch_packed(&packed),
            stemmer.stem_batch(&words),
            "batch form diverged ({label})"
        );
    }
}

/// PR 6 acceptance property: the lane-parallel SIMD kernel is
/// bit-identical to the pinned scalar packed kernel and the scalar
/// reference — root, kind, and cut — over 10k inflected corpus words in
/// both infix configs. Every compiled-in path is forced explicitly
/// (portable scalar always; AVX2/NEON when the host supports them), and
/// the public dispatchers (`stem_batch_packed`, `stem_batch_simd`,
/// `stem_batch`) must agree with whatever `AMA_SIMD`/auto-detection
/// picked. Odd tails exercise the remainder-lane path: 10k % 8 != 0
/// batches split at every width via sub-slices.
#[test]
fn prop_simd_kernel_matches_packed_and_reference() {
    let r = roots();
    let with = Stemmer::with_defaults(r.clone());
    let without = Stemmer::new(r.clone(), StemmerConfig { infix_processing: false });
    let mut rng = SplitMix64::new(0x0917_0008);
    let classes =
        [corpus::FormClass::Direct, corpus::FormClass::Infix, corpus::FormClass::Unstemmable];

    let mut lexicon: Vec<[u16; 4]> = Vec::new();
    for t in r.tri_rows() {
        lexicon.push([t[0], t[1], t[2], 0]);
    }
    for q in r.quad_rows() {
        lexicon.push(*q);
    }
    for b in r.bi_rows() {
        lexicon.push([b[0], b[1], 0, 0]);
    }

    let mut words: Vec<ArabicWord> = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        let gold = *rng.choose(&lexicon);
        let class = *rng.choose(&classes);
        words.push(corpus::inflect(&gold, class, &mut rng));
    }
    let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();
    let paths = ama::simd::available_paths();
    assert!(
        paths.contains(&ama::simd::SimdPath::Scalar),
        "the portable path must always be available"
    );
    for (stemmer, label) in [(&with, "with-infix"), (&without, "no-infix")] {
        let baseline = stemmer.stem_batch_packed_scalar(&packed);
        for (i, (w, want)) in words.iter().zip(&baseline).enumerate() {
            assert_eq!(
                *want,
                stemmer.stem_reference(w),
                "case {i} ({label}): scalar kernel != reference for {w:?}"
            );
        }
        for &path in &paths {
            let got = ama::simd::stem_batch_simd_with(stemmer, &packed, path);
            assert_eq!(got.len(), baseline.len());
            for (i, (g, want)) in got.iter().zip(&baseline).enumerate() {
                assert_eq!(
                    g, want,
                    "case {i} ({label}, {}): simd != scalar kernel for {:?}",
                    path.name(),
                    words[i]
                );
            }
            // odd widths hit the remainder lanes and the wide/narrow cut
            for width in [1usize, 7, 15, 16, 17, 63, 100] {
                let got = ama::simd::stem_batch_simd_with(stemmer, &packed[..width], path);
                assert_eq!(
                    got,
                    baseline[..width],
                    "width {width} ({label}, {})",
                    path.name()
                );
            }
        }
        // the public dispatchers agree regardless of which path is active
        assert_eq!(stemmer.stem_batch_packed(&packed), baseline, "dispatcher ({label})");
        assert_eq!(stemmer.stem_batch_simd(&packed), baseline, "simd dispatcher ({label})");
        assert_eq!(stemmer.stem_batch(&words), baseline, "array dispatcher ({label})");
    }
}

/// PR 5 acceptance property: the HLO interpreter executing the emitted
/// stemmer artifact is bit-identical to both `stem_packed` and the
/// scalar `stem_reference` — root, kind, and cut — over 10k randomly
/// inflected corpus words, in both infix configs (the no-infix graph is
/// a separately emitted module, mirroring `StemmerConfig`). This pins
/// the whole self-hosting artifact cycle: `emit::stemmer_hlo` →
/// `interp::Module` → batched execution with padding and chunking.
#[test]
fn prop_interp_engine_matches_packed_and_reference() {
    use ama::runtime::{emit, interp::InterpBackend, Backend as _};
    let r = roots();
    let mut rng = SplitMix64::new(0x0917_0007);
    let classes =
        [corpus::FormClass::Direct, corpus::FormClass::Infix, corpus::FormClass::Unstemmable];

    let mut lexicon: Vec<[u16; 4]> = Vec::new();
    for t in r.tri_rows() {
        lexicon.push([t[0], t[1], t[2], 0]);
    }
    for q in r.quad_rows() {
        lexicon.push(*q);
    }
    for b in r.bi_rows() {
        lexicon.push([b[0], b[1], 0, 0]);
    }

    let mut words: Vec<ArabicWord> = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        let gold = *rng.choose(&lexicon);
        let class = *rng.choose(&classes);
        words.push(corpus::inflect(&gold, class, &mut rng));
    }
    let packed: Vec<PackedWord> = words.iter().map(PackedWord::pack).collect();

    for infix in [true, false] {
        let text = emit::stemmer_hlo(256, infix);
        let engine = InterpBackend::from_texts([(text.as_str(), "emitted")], &r).unwrap();
        let sw = Stemmer::new(r.clone(), StemmerConfig { infix_processing: infix });
        let got = engine.stem_chunk(&words).unwrap();
        assert_eq!(got.len(), words.len());
        for (case, ((w, &p), g)) in words.iter().zip(&packed).zip(&got).enumerate() {
            assert_eq!(
                *g,
                sw.stem_packed(p),
                "case {case} (infix={infix}): interpreter != stem_packed for {w:?}"
            );
            assert_eq!(
                *g,
                sw.stem_reference(w),
                "case {case} (infix={infix}): interpreter != stem_reference for {w:?}"
            );
        }
    }
}

/// PR 4 acceptance property, part 3: with the memoizing cache in front
/// of the registry, a mixed-options request stream served cold and then
/// warm returns identical results (hit path ≡ miss path), trace
/// requests always trace, and the counters see the warm pass.
#[test]
fn prop_cache_warm_equals_cold_mixed_options() {
    let r = roots();
    let mut rng = SplitMix64::new(0x0917_0006);
    let words: Vec<ArabicWord> = (0..400).map(|_| random_word(&mut rng)).collect();
    let c = Coordinator::start_registry_cached(
        CoordinatorConfig { workers: 2, max_batch: 64, ..Default::default() },
        r,
        StemmerConfig::default(),
        8192,
    );
    let h = c.handle();
    let mut option_mix: Vec<AnalyzeOptions> = Vec::new();
    for algorithm in Algorithm::ALL {
        for infix in [None, Some(false)] {
            option_mix.push(AnalyzeOptions { algorithm, infix, want_trace: false });
        }
    }
    option_mix.push(AnalyzeOptions { want_trace: true, ..Default::default() });
    let cold: Vec<Vec<ama::analysis::Analysis>> = option_mix
        .iter()
        .map(|o| h.analyze_bulk(&words, o.into()).unwrap())
        .collect();
    let warm: Vec<Vec<ama::analysis::Analysis>> = option_mix
        .iter()
        .map(|o| h.analyze_bulk(&words, o.into()).unwrap())
        .collect();
    for ((opts, cold_pass), warm_pass) in option_mix.iter().zip(&cold).zip(&warm) {
        assert_eq!(warm_pass, cold_pass, "warm != cold under {opts:?}");
        if opts.want_trace {
            assert!(
                warm_pass.iter().all(|a| a.trace.is_some()),
                "trace requests must trace on the (bypassed) warm pass too"
            );
        }
    }
    let snap = c.metrics().snapshot();
    assert!(snap.cache_hits > 0, "warm pass produced no hits: {snap:?}");
    assert_eq!(snap.errors, 0);
    c.shutdown();
}

/// The fused batch kernels agree with the scalar paths on random words.
#[test]
fn prop_batch_kernels_equal_reference() {
    let r = roots();
    let sw = Stemmer::with_defaults(r.clone());
    let mut rng = SplitMix64::new(0x50A0);
    let words: Vec<ArabicWord> = (0..5000).map(|_| random_word(&mut rng)).collect();
    let expected: Vec<_> = words.iter().map(|w| sw.stem_reference(w)).collect();
    assert_eq!(sw.stem_batch(&words), expected);
    assert_eq!(sw.stem_batch_parallel(&words, 4), expected);
}

/// Dictionary roots stem to themselves (identity on the fixpoint set).
#[test]
fn prop_roots_are_fixpoints() {
    let r = roots();
    let sw = Stemmer::with_defaults(r.clone());
    for root in r.tri_rows().iter().take(500) {
        let w = ArabicWord::from_codes(root);
        let res = sw.stem(&w);
        assert_eq!(res.kind, MatchKind::Tri, "root {w:?}");
        assert_eq!(&res.root[..3], root);
        assert_eq!(res.cut, 0);
    }
    for root in r.quad_rows().iter().take(200) {
        let w = ArabicWord::from_codes(root);
        let res = sw.stem(&w);
        // a quad root may contain a trilateral substring match first; but
        // if quad is returned it must be the root itself
        if res.kind == MatchKind::Quad {
            assert_eq!(res.root, *root);
        }
    }
}

/// Fuzz: the three implementations agree on fully random garbage.
#[test]
fn prop_fuzz_simulators_equal_software() {
    let r = roots();
    let sw = Stemmer::with_defaults(r.clone());
    let cfg = DatapathConfig { infix_units: true };
    let mut rng = SplitMix64::new(0xF00D);
    let words: Vec<ArabicWord> = (0..2000).map(|_| random_word(&mut rng)).collect();
    let expected = sw.stem_batch(&words);
    let (np, _) = NonPipelinedProcessor::new(r.clone(), cfg).run(&words);
    let (pp, _) = PipelinedProcessor::new(r.clone(), cfg).run(&words);
    assert_eq!(np, expected);
    assert_eq!(pp, expected);
}

/// Encoding invariants: normalized, bounded, diacritic-free.
#[test]
fn prop_encode_invariants() {
    let mut rng = SplitMix64::new(0xE2C0DE);
    for _ in 0..2000 {
        // random unicode soup biased toward the Arabic block
        let n = rng.index(30);
        let s: String = (0..n)
            .filter_map(|_| {
                let c = match rng.index(4) {
                    0 => 0x0600 + rng.below(0xFF) as u32,
                    1 => 0x0621 + rng.below(42) as u32,
                    2 => rng.below(0x80) as u32,
                    _ => 0x064B + rng.below(8) as u32, // diacritics
                };
                char::from_u32(c)
            })
            .collect();
        let w = ArabicWord::encode(&s);
        assert!(w.len <= chars::MAX_WORD);
        for (i, &c) in w.chars.iter().enumerate() {
            if i < w.len {
                assert!(!chars::is_diacritic(c), "diacritic survived in {s:?}");
                assert_ne!(c, chars::ALEF_HAMZA_ABOVE, "unnormalized alef in {s:?}");
                assert_ne!(c, chars::ALEF_MAKSURA);
            } else {
                assert_eq!(c, chars::PAD);
            }
        }
    }
}

/// Coordinator invariants under random configs and workloads: order
/// preserved, every request answered exactly once, word counts conserved.
#[test]
fn prop_coordinator_conservation() {
    let r = roots();
    let mut rng = SplitMix64::new(0xC00D);
    for case in 0..8 {
        let workers = 1 + rng.index(4);
        let max_batch = 1 + rng.index(128);
        let n = 50 + rng.index(400);
        let words: Vec<ArabicWord> = (0..n).map(|_| random_word(&mut rng)).collect();
        let sw = Stemmer::with_defaults(r.clone());
        let expected = sw.stem_batch(&words);

        let r2 = r.clone();
        let factory: BackendFactory = Box::new(move |_| {
            Ok(Box::new(SoftwareBackend(Stemmer::with_defaults(r2.clone()))))
        });
        let coord = Coordinator::start(
            CoordinatorConfig {
                workers,
                max_batch,
                queue_capacity: 64,
                ..Default::default()
            },
            factory,
        );
        let got = coord.handle().stem_stream(&words).unwrap();
        assert_eq!(got, expected, "case {case} (workers={workers}, batch={max_batch})");
        let snap = coord.metrics().snapshot();
        assert_eq!(snap.words, n as u64, "case {case}: word count not conserved");
        assert_eq!(snap.requests, n as u64, "case {case}");
        coord.shutdown();
    }
}

/// Queue conservation under random concurrent interleavings.
#[test]
fn prop_queue_conservation() {
    let mut rng = SplitMix64::new(0x0BEE);
    for _ in 0..5 {
        let cap = 1 + rng.index(16);
        let producers = 1 + rng.index(4);
        let per = 100 + rng.index(200);
        let q: Arc<BoundedQueue<u64>> = BoundedQueue::new(cap);
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut sum = 0u64;
                for i in 0..per {
                    let v = (p * 10_000 + i) as u64;
                    sum += v;
                    q.push(v).unwrap();
                }
                sum
            }));
        }
        let consumer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut sum = 0u64;
                while let Ok(v) = q.pop() {
                    sum += v;
                }
                sum
            })
        };
        let pushed: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        q.close();
        let popped = consumer.join().unwrap();
        assert_eq!(pushed, popped);
    }
}

/// Corpus generator invariants: calibrated class mixes actually hold.
#[test]
fn prop_corpus_class_rates() {
    let r = roots();
    let c = corpus::generate(&r, &CorpusConfig::small(20_000, 31));
    let infix = c.tokens.iter().filter(|t| t.class == corpus::FormClass::Infix).count();
    let unstem =
        c.tokens.iter().filter(|t| t.class == corpus::FormClass::Unstemmable).count();
    let n = c.tokens.len() as f64;
    // direct should dominate; unstemmable should stay a modest minority
    assert!((infix as f64) / n > 0.10, "infix rate {infix}");
    assert!((unstem as f64) / n < 0.35, "unstemmable rate {unstem}");
}

/// PR 3 acceptance property: all four engines, driven through the
/// unified `Analyzer` trait at default options, are bit-identical to
/// their pre-redesign inherent `stem` methods on 10k randomly inflected
/// words — and the provided/overridden batch paths agree with the scalar
/// path.
#[test]
fn prop_analyzer_conformance_10k_inflected() {
    let r = roots();
    let registry = AnalyzerRegistry::new(r.clone());
    let lb = Stemmer::with_defaults(r.clone());
    let kh = ama::khoja::KhojaStemmer::new(r.clone());
    let li = ama::light::LightStemmer::new(r.clone());
    let vo = ama::light::VotingAnalyzer::new(r.clone());
    let mut rng = SplitMix64::new(0x0917_0003);
    let classes =
        [corpus::FormClass::Direct, corpus::FormClass::Infix, corpus::FormClass::Unstemmable];

    let mut lexicon: Vec<[u16; 4]> = Vec::new();
    for t in r.tri_rows() {
        lexicon.push([t[0], t[1], t[2], 0]);
    }
    for q in r.quad_rows() {
        lexicon.push(*q);
    }
    for b in r.bi_rows() {
        lexicon.push([b[0], b[1], 0, 0]);
    }

    let mut words: Vec<ArabicWord> = Vec::with_capacity(10_000);
    for _ in 0..10_000 {
        let gold = *rng.choose(&lexicon);
        let class = *rng.choose(&classes);
        words.push(corpus::inflect(&gold, class, &mut rng));
    }

    let defaults = AnalyzeOptions::default();
    for (case, w) in words.iter().enumerate() {
        assert_eq!(
            registry.get(Algorithm::Linguistic).analyze(w, &defaults).result,
            lb.stem(w),
            "linguistic case {case}: {w:?}"
        );
        assert_eq!(
            registry.get(Algorithm::Khoja).analyze(w, &defaults).result,
            kh.stem(w),
            "khoja case {case}: {w:?}"
        );
        assert_eq!(
            registry.get(Algorithm::Light).analyze(w, &defaults).result,
            li.stem(w),
            "light case {case}: {w:?}"
        );
        assert_eq!(
            registry.get(Algorithm::Voting).analyze(w, &defaults).result,
            vo.stem(w),
            "voting case {case}: {w:?}"
        );
    }
    // Batch forms (the provided trait method, and the SoA override for
    // the linguistic engine) equal the scalar path.
    for algo in Algorithm::ALL {
        let engine = registry.get(algo);
        let batch = engine.stem_batch(&words);
        for (i, (b, w)) in batch.iter().zip(&words).enumerate() {
            assert_eq!(*b, engine.analyze(w, &defaults).result, "{algo} batch case {i}");
        }
    }
    // A per-request no-infix override equals a dedicated no-infix engine.
    let no_infix = Stemmer::new(r.clone(), StemmerConfig { infix_processing: false });
    let opts_off = AnalyzeOptions { infix: Some(false), ..Default::default() };
    for (case, w) in words.iter().take(2000).enumerate() {
        assert_eq!(
            registry.get(Algorithm::Linguistic).analyze(w, &opts_off).result,
            no_infix.stem(w),
            "no-infix override case {case}: {w:?}"
        );
    }
}

/// Random AMA/1 envelopes and replies survive encode → parse bit-exactly,
/// including hostile string content (quotes, backslashes, control
/// characters, surrogate-pair-requiring emoji, Arabic).
#[test]
fn prop_protocol_roundtrip() {
    let mut rng = SplitMix64::new(0xA1A1);
    let tricky = ['"', '\\', '\n', '\t', '\r', '\u{0001}', 'ل', 'ع', 'ب', '😀', 'x', ' ', '{'];
    let random_string = |rng: &mut SplitMix64| -> String {
        let n = rng.index(12);
        (0..n).map(|_| *rng.choose(&tricky)).collect()
    };
    for case in 0..500 {
        let algorithm = Algorithm::from_u8(rng.below(4) as u8);
        let infix = match rng.below(3) {
            0 => None,
            1 => Some(true),
            _ => Some(false),
        };
        let opts = AnalyzeOptions { algorithm, infix, want_trace: rng.below(2) == 1 };
        // ids must stay f64-exact (< 2^53) to round-trip through JSON
        let id = rng.next_u64() & ((1 << 53) - 1);
        let n_words = rng.index(5);
        let words: Vec<String> = (0..n_words).map(|_| random_string(&mut rng)).collect();
        let env = Envelope::analyze(id, words, opts);
        let line = env.to_json();
        let back = Envelope::parse(&line)
            .unwrap_or_else(|e| panic!("case {case}: rejected own encoding {line:?}: {e:?}"));
        assert_eq!(back, env, "case {case}");

        // a random reply round-trips too
        let n_results = rng.index(3);
        let results: Vec<WireResult> = (0..n_results)
            .map(|_| WireResult {
                word: random_string(&mut rng),
                root: random_string(&mut rng),
                kind: MatchKind::from_u8(rng.below(6) as u8),
                cut: rng.below(6) as u8,
                algo: Algorithm::from_u8(rng.below(4) as u8),
                // constructed from 4-decimal fractions so {:.4} is exact
                confidence: rng.below(10_001) as f32 / 10_000.0,
                votes: rng.below(4) as u8,
                trace: if rng.below(4) == 0 {
                    Some(vec![("fetch".to_string(), random_string(&mut rng))])
                } else {
                    None
                },
            })
            .collect();
        let reply = Reply::Results { id, results };
        let line = reply.to_json();
        let back = Reply::parse(&line)
            .unwrap_or_else(|e| panic!("case {case}: rejected own reply {line:?}: {e}"));
        assert_eq!(back, reply, "case {case}");
    }
}

/// Malformed-frame robustness: every strict prefix of a valid envelope
/// is rejected (never panics, never parses), and random byte mutations
/// never panic the parser.
#[test]
fn prop_protocol_malformed_frames_rejected() {
    let mut rng = SplitMix64::new(0xBADF);
    let env = Envelope::analyze(
        7,
        vec!["سيلعبون".to_string(), "q\"uo\\te".to_string()],
        AnalyzeOptions {
            algorithm: Algorithm::Voting,
            infix: Some(true),
            want_trace: true,
        },
    );
    let line = env.to_json();
    // every strict prefix (at char boundaries) must fail cleanly
    for (pos, _) in line.char_indices() {
        let prefix = &line[..pos];
        assert!(
            Envelope::parse(prefix).is_err(),
            "strict prefix parsed: {prefix:?}"
        );
    }
    // random single-char mutations: parse must never panic; when it
    // succeeds the result must still be a well-formed envelope (which
    // Envelope's types guarantee — so just exercise it)
    let chars: Vec<char> = line.chars().collect();
    for _ in 0..500 {
        let mut mutated = chars.clone();
        let i = rng.index(mutated.len());
        mutated[i] = *rng.choose(&['x', '{', '}', '"', ':', ',', '0', '\\', 'ع']);
        let s: String = mutated.iter().collect();
        let _ = Envelope::parse(&s); // no panic is the property
    }
}

/// The no-infix stemmer is a strict subset of the with-infix stemmer:
/// whenever no-infix finds a root, with-infix finds the same root.
#[test]
fn prop_infix_is_strict_extension() {
    let r = roots();
    let with = Stemmer::with_defaults(r.clone());
    let without = Stemmer::new(r.clone(), StemmerConfig { infix_processing: false });
    let mut rng = SplitMix64::new(0x5B5E7);
    for _ in 0..3000 {
        let w = random_word(&mut rng);
        let a = without.stem(&w);
        if a.kind != MatchKind::None {
            let b = with.stem(&w);
            assert_eq!(a, b, "word {w:?}");
        }
    }
}
