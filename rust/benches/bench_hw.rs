//! Tables 4 & 5 + Fig 16 hardware rows — the FPGA simulator: cycle-model
//! throughput (exact, from Fmax and cycle counts) plus the wall-clock cost
//! of simulating, and the physical model report.

use ama::bench::{bench_words, config_from_env, header};
use ama::chars::ArabicWord;
use ama::corpus::{self, CorpusConfig};
use ama::hw::area::Organization;
use ama::hw::{
    DatapathConfig, NonPipelinedProcessor, PhysicalModel, PipelinedProcessor, Processor,
};
use ama::roots::RootSet;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let cfg = config_from_env();
    let roots = if Path::new("data/roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(Path::new("data")).expect("load roots"))
    } else {
        Arc::new(RootSet::builtin_mini())
    };
    let quran = corpus::generate(&roots, &CorpusConfig::quran());
    let words: Vec<ArabicWord> = quran.tokens.iter().map(|t| t.word).collect();
    let n = words.len() as u64;
    let dp = DatapathConfig::default();

    header("bench_hw — Table 4/5 + Fig 16 hardware rows");

    // Simulator wall-clock (how fast the *simulation* runs on this host).
    let r = bench_words("sim/non-pipelined (wall-clock)", &cfg, n, || {
        let mut p = NonPipelinedProcessor::new(roots.clone(), dp);
        let (res, _) = p.run(&words);
        std::hint::black_box(res.len());
    });
    println!("{r}");
    let r = bench_words("sim/pipelined (wall-clock)", &cfg, n, || {
        let mut p = PipelinedProcessor::new(roots.clone(), dp);
        let (res, _) = p.run(&words);
        std::hint::black_box(res.len());
    });
    println!("{r}");

    // Modeled FPGA throughput (the Fig 16 numbers).
    let np = NonPipelinedProcessor::new(roots.clone(), dp);
    let pp = PipelinedProcessor::new(roots.clone(), dp);
    println!("\nmodeled FPGA throughput (Fig 16):");
    for (name, th, paper) in [
        ("non-pipelined", np.throughput_wps(n), 2.08e6),
        ("pipelined", pp.throughput_wps(n), 10.78e6),
    ] {
        println!(
            "  {name:<16} {:>10.3} MWps   (paper {:.2} MWps, delta {:+.2}%)",
            th / 1e6,
            paper / 1e6,
            100.0 * (th - paper) / paper
        );
    }

    // Table 4 + Table 5.
    let model = PhysicalModel::new(dp);
    println!("\nTable 4 (physical model):");
    for org in [Organization::NonPipelined, Organization::Pipelined] {
        let rep = model.report(org);
        println!(
            "  {:?}: Fmax {:.2} MHz, {} ALUTs ({:.0}%), {} LRs, {:.2} mW (structural Fmax {:.1} MHz)",
            org,
            rep.fmax_mhz,
            rep.luts,
            100.0 * rep.lut_utilization,
            rep.lregs,
            rep.power_mw,
            rep.fmax_structural_mhz
        );
    }
    println!("\nTable 5 (throughput-to-area):");
    for (corpus_name, cn) in
        [("quran", corpus::QURAN_WORDS as u64), ("ankabut", corpus::ANKABUT_WORDS as u64)]
    {
        let th_np = np.throughput_wps(cn);
        let th_pp = pp.throughput_wps(cn);
        let rep_np = model.report(Organization::NonPipelined);
        let rep_pp = model.report(Organization::Pipelined);
        println!(
            "  {corpus_name:<8} TH/LUT: NP {:>7.2}  P {:>7.2}   TH/LR: NP {:>8.1}  P {:>9.1}",
            th_np / rep_np.luts as f64,
            th_pp / rep_pp.luts as f64,
            th_np / rep_np.lregs as f64,
            th_pp / rep_pp.lregs as f64,
        );
    }
    println!("  paper quran: TH/LUT NP 24.22 P 151.85; TH/LR NP 2438 P 10197");

    // Ablation: infix units in hardware (the paper's §7 future work).
    let with_infix = PhysicalModel::new(DatapathConfig { infix_units: true });
    let rep = with_infix.report(Organization::Pipelined);
    println!(
        "\nablation — pipelined core with infix units: {} ALUTs (+{}), Fmax {:.2} MHz",
        rep.luts,
        rep.luts - model.report(Organization::Pipelined).luts,
        rep.fmax_mhz
    );
}
