//! Runtime microbenchmarks: the AOT stemmer artifact executed from rust,
//! per batch size — the HLO interpreter in the default build, real PJRT
//! with `--features pjrt`. Also the batch-size ablation that motivates
//! the batcher's `max_batch=256`. Artifacts are emitted in-process when
//! absent, so the bench runs offline with no `make artifacts` step.

use ama::bench::{bench_words, config_from_env, header};
use ama::chars::ArabicWord;
use ama::corpus::{self, CorpusConfig};
use ama::roots::RootSet;
use ama::runtime::Engine;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let cfg = config_from_env();
    let mut artifacts = ama::runtime::default_artifacts_dir();
    if !artifacts.join("stemmer_b1.hlo.txt").exists() {
        artifacts =
            std::env::temp_dir().join(format!("ama_bench_runtime_artifacts_{}", std::process::id()));
        ama::runtime::emit::write_artifacts(&artifacts, ama::runtime::BATCHES)
            .expect("emit artifacts");
        eprintln!("bench_runtime: emitted artifacts to {}", artifacts.display());
    }
    let roots = if Path::new("data/roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(Path::new("data")).expect("load roots"))
    } else {
        Arc::new(RootSet::builtin_mini())
    };
    let engine = Engine::load(&artifacts, &roots).expect("load engine");
    let c = corpus::generate(&roots, &CorpusConfig::small(4096, 11));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();

    header("bench_runtime — execution of the AOT stemmer artifact");
    println!(
        "backend: {}, loaded batch sizes: {:?}",
        engine.backend_name(),
        engine.batch_sizes()
    );

    // Per-batch-size throughput (batch-size ablation).
    for &b in &engine.batch_sizes() {
        let chunk = &words[..b];
        let r = bench_words(&format!("runtime/stemmer_b{b}"), &cfg, b as u64, || {
            let res = engine.stem_chunk(chunk).expect("exec");
            std::hint::black_box(res.len());
        });
        println!("{r}");
    }

    // Sustained throughput: stream 4096 words through the best batch size.
    let r = bench_words("runtime/stream-4096", &cfg, words.len() as u64, || {
        let res = engine.stem_chunk(&words).expect("exec");
        std::hint::black_box(res.len());
    });
    println!("{r}");
}
