//! PJRT runtime microbenchmarks: the AOT JAX/Pallas artifact executed from
//! rust, per batch size — the L2/L1 hot path the coordinator drives. Also
//! the batch-size ablation that motivates the batcher's `max_batch=256`.

use ama::bench::{bench_words, config_from_env, header};
use ama::chars::ArabicWord;
use ama::corpus::{self, CorpusConfig};
use ama::roots::RootSet;
use ama::runtime::Engine;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let cfg = config_from_env();
    let artifacts = ama::runtime::default_artifacts_dir();
    if !artifacts.join("stemmer_b1.hlo.txt").exists() {
        eprintln!("bench_runtime: no artifacts under {} — run `make artifacts`", artifacts.display());
        return;
    }
    let roots = if Path::new("data/roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(Path::new("data")).expect("load roots"))
    } else {
        Arc::new(RootSet::builtin_mini())
    };
    let engine = Engine::load(&artifacts, &roots).expect("load engine");
    let c = corpus::generate(&roots, &CorpusConfig::small(4096, 11));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();

    header("bench_runtime — PJRT execution of the AOT stemmer artifact");
    println!("loaded batch sizes: {:?}", engine.batch_sizes());

    // Per-batch-size throughput (batch-size ablation).
    for &b in &engine.batch_sizes() {
        let chunk = &words[..b];
        let r = bench_words(&format!("pjrt/stemmer_b{b}"), &cfg, b as u64, || {
            let res = engine.stem_chunk(chunk).expect("exec");
            std::hint::black_box(res.len());
        });
        println!("{r}");
    }

    // Sustained throughput: stream 4096 words through the best batch size.
    let r = bench_words("pjrt/stream-4096", &cfg, words.len() as u64, || {
        let res = engine.stem_chunk(&words).expect("exec");
        std::hint::black_box(res.len());
    });
    println!("{r}");
}
