//! Fig 16 (software row) — throughput of the sequential software stemmer
//! over the Quran-calibrated corpus, with and without infix processing,
//! plus the Khoja baseline. Paper reference: 373.3 Wps on a six-core Xeon
//! (Java); our rust substrate is far faster — the *ratios* to the hardware
//! models are what reproduce Fig 16's shape.

use ama::bench::{bench_words, config_from_env, header};
use ama::chars::ArabicWord;
use ama::corpus::{self, CorpusConfig};
use ama::khoja::KhojaStemmer;
use ama::roots::RootSet;
use ama::stemmer::{Stemmer, StemmerConfig};
use std::path::Path;
use std::sync::Arc;

fn main() {
    let cfg = config_from_env();
    let roots = if Path::new("data/roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(Path::new("data")).expect("load roots"))
    } else {
        Arc::new(RootSet::builtin_mini())
    };
    let quran = corpus::generate(&roots, &CorpusConfig::quran());
    let words: Vec<ArabicWord> = quran.tokens.iter().map(|t| t.word).collect();
    let n = words.len() as u64;

    header("bench_software — Fig 16 software row (Quran corpus, 77,476 words)");

    // The retained scalar baseline vs the fused table-driven hot path —
    // the PR 1 acceptance ratio (see `ama bench json` / BENCH_PR1.json).
    let with = Stemmer::with_defaults(roots.clone());
    let r = bench_words("software/stem_reference (scalar)", &cfg, n, || {
        let mut acc = 0usize;
        for w in &words {
            acc += with.stem_reference(w).kind as usize;
        }
        std::hint::black_box(acc);
    });
    println!("{r}");
    let th_ref = r.wps().unwrap();

    let r = bench_words("software/with-infix", &cfg, n, || {
        let mut acc = 0usize;
        for w in &words {
            acc += with.stem(w).kind as usize;
        }
        std::hint::black_box(acc);
    });
    println!("{r}");
    let th_sw = r.wps().unwrap();
    println!("  fused stem vs stem_reference: {:.2}x", th_sw / th_ref);

    let r = bench_words("software/stem_batch (SoA)", &cfg, n, || {
        let res = with.stem_batch(&words);
        std::hint::black_box(res.len());
    });
    println!("{r}");

    let threads = std::thread::available_parallelism().map_or(1, |p| p.get());
    let r = bench_words(&format!("software/stem_batch_parallel t={threads}"), &cfg, n, || {
        let res = with.stem_batch_parallel(&words, threads);
        std::hint::black_box(res.len());
    });
    println!("{r}");

    let without = Stemmer::new(roots.clone(), StemmerConfig { infix_processing: false });
    let r = bench_words("software/no-infix", &cfg, n, || {
        let mut acc = 0usize;
        for w in &words {
            acc += without.stem(w).kind as usize;
        }
        std::hint::black_box(acc);
    });
    println!("{r}");

    let khoja = KhojaStemmer::new(roots.clone());
    let r = bench_words("khoja-baseline", &cfg, n, || {
        let mut acc = 0usize;
        for w in &words {
            acc += khoja.stem(w).kind as usize;
        }
        std::hint::black_box(acc);
    });
    println!("{r}");

    // Fig 16 ratios against the hardware throughput models.
    use ama::hw::{DatapathConfig, NonPipelinedProcessor, PipelinedProcessor, Processor};
    let np = NonPipelinedProcessor::new(roots.clone(), DatapathConfig::default());
    let pp = PipelinedProcessor::new(roots, DatapathConfig::default());
    println!("\nFig 16 shape (this machine's software vs paper's FPGA models):");
    println!("  software measured:        {:>12.1} Wps", th_sw);
    println!(
        "  non-pipelined (model):    {:>12.1} Wps   ({:.1}x software)",
        np.throughput_wps(n),
        np.throughput_wps(n) / th_sw
    );
    println!(
        "  pipelined (model):        {:>12.1} Wps   ({:.1}x software)",
        pp.throughput_wps(n),
        pp.throughput_wps(n) / th_sw
    );
    println!("  paper: 373.3 Wps / 2.08 MWps (5,571x) / 10.78 MWps (28,873x)");
}
