//! Fig 17 — pipelined-over-non-pipelined speedup as a function of the
//! number of analyzed input words, from 1 to 10⁶ (the pipeline fill/drain
//! effect). Also validates the modeled curve against the cycle-accurate
//! simulator's actual cycle counts on small N.

use ama::bench::header;
use ama::chars::ArabicWord;
use ama::corpus::{self, CorpusConfig};
use ama::hw::{DatapathConfig, NonPipelinedProcessor, PipelinedProcessor, Processor};
use ama::roots::RootSet;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let roots = if Path::new("data/roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(Path::new("data")).expect("load roots"))
    } else {
        Arc::new(RootSet::builtin_mini())
    };
    let np = NonPipelinedProcessor::new(roots.clone(), DatapathConfig::default());
    let pp = PipelinedProcessor::new(roots.clone(), DatapathConfig::default());

    header("bench_sweep — Fig 17: speedup vs input word count");
    println!("{:>10} {:>12} {:>12} {:>16} {:>16} {:>9}", "N", "NP cycles", "P cycles", "NP Wps", "P Wps", "speedup");
    for n in [1u64, 2, 5, 10, 20, 50, 100, 500, 1_000, 10_000, 77_476, 980, 1_000_000] {
        let a = np.throughput_wps(n);
        let b = pp.throughput_wps(n);
        println!(
            "{:>10} {:>12} {:>12} {:>16.0} {:>16.0} {:>8.2}x",
            n,
            np.cycles_for(n),
            pp.cycles_for(n),
            a,
            b,
            b / a
        );
    }
    println!("asymptote 5·f_p/f_np = {:.3}x (paper: 5.18; quran 5.18, ankabut 5.16)", 5.0 * 10.78 / 10.4);

    // Validate the model against the cycle-accurate simulator.
    println!("\ncycle-count validation (simulator vs model):");
    let c = corpus::generate(&roots, &CorpusConfig::small(200, 9));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();
    for n in [1usize, 7, 64, 200] {
        let mut np = NonPipelinedProcessor::new(roots.clone(), DatapathConfig::default());
        let mut pp = PipelinedProcessor::new(roots.clone(), DatapathConfig::default());
        let (_, s1) = np.run(&words[..n]);
        let (_, s2) = pp.run(&words[..n]);
        assert_eq!(s1.cycles, np.cycles_for(n as u64), "np cycle model");
        assert_eq!(s2.cycles, pp.cycles_for(n as u64), "p cycle model");
        println!("  N={n:<5} np {} cycles, pipelined {} cycles — model exact", s1.cycles, s2.cycles);
    }
}
