//! End-to-end coordinator benchmarks: requests → bounded queue → dynamic
//! batcher → backend → replies. Includes the batching-policy ablation
//! (max_batch sweep) DESIGN.md §7 calls out, over both the software and
//! runtime backends.

use ama::bench::{bench_words, config_from_env, header};
use ama::chars::ArabicWord;
use ama::coordinator::{
    BackendFactory, Coordinator, CoordinatorConfig, RuntimeBackend, SoftwareBackend,
};
use ama::corpus::{self, CorpusConfig};
use ama::roots::RootSet;
use ama::stemmer::Stemmer;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn sw_factory(roots: Arc<RootSet>) -> BackendFactory {
    Box::new(move |_| Ok(Box::new(SoftwareBackend(Stemmer::with_defaults(roots.clone())))))
}

fn runtime_factory(roots: Arc<RootSet>) -> BackendFactory {
    let artifacts = ama::runtime::default_artifacts_dir();
    Box::new(move |_| {
        Ok(Box::new(RuntimeBackend(ama::runtime::Engine::load(&artifacts, &roots)?)))
    })
}

fn main() {
    let cfg = config_from_env();
    let roots = if Path::new("data/roots_trilateral.txt").exists() {
        Arc::new(RootSet::load(Path::new("data")).expect("load roots"))
    } else {
        Arc::new(RootSet::builtin_mini())
    };
    let c = corpus::generate(&roots, &CorpusConfig::small(8192, 13));
    let words: Vec<ArabicWord> = c.tokens.iter().map(|t| t.word).collect();
    let n = words.len() as u64;

    header("bench_coordinator — end-to-end serving path");

    // Batching-policy ablation over the software backend.
    for max_batch in [1usize, 16, 64, 256, 1024] {
        let coord = Coordinator::start(
            CoordinatorConfig {
                max_batch,
                max_wait: Duration::from_micros(200),
                queue_capacity: 8192,
                workers: 1,
            },
            sw_factory(roots.clone()),
        );
        let h = coord.handle();
        let r = bench_words(&format!("coordinator/sw max_batch={max_batch}"), &cfg, n, || {
            let res = h.stem_stream(&words).expect("stream");
            std::hint::black_box(res.len());
        });
        println!("{r}  (mean batch {:.1})", coord.metrics().mean_batch_size());
        coord.shutdown();
    }

    // Reply-slab bulk path (windowed tickets, zero allocation per word)
    // vs the per-word submit/wait ping-pong.
    {
        let coord = Coordinator::start(
            CoordinatorConfig {
                max_batch: 256,
                max_wait: Duration::from_micros(200),
                queue_capacity: 8192,
                workers: 1,
            },
            sw_factory(roots.clone()),
        );
        let h = coord.handle();
        let r = bench_words("coordinator/sw bulk (slab) max_batch=256", &cfg, n, || {
            let res = h.stem_bulk(&words).expect("bulk");
            std::hint::black_box(res.len());
        });
        println!("{r}");
        // Per-word ping-pong: one submit → wait round-trip at a time, the
        // latency-bound worst case the pipelined protocol exists to avoid.
        let few = &words[..512.min(words.len())];
        let r = bench_words("coordinator/sw submit ping-pong", &cfg, few.len() as u64, || {
            for w in few {
                let res = h.stem(*w).expect("stem");
                std::hint::black_box(res.cut);
            }
        });
        println!("{r}");
        let snap = coord.metrics().snapshot();
        println!("  saturation: queue_full={} slab_waits={}", snap.queue_full_events, snap.slab_waits);
        coord.shutdown();
    }

    // Worker-count scaling.
    for workers in [1usize, 2, 4] {
        let coord = Coordinator::start(
            CoordinatorConfig {
                max_batch: 256,
                max_wait: Duration::from_micros(200),
                queue_capacity: 8192,
                workers,
            },
            sw_factory(roots.clone()),
        );
        let h = coord.handle();
        let r = bench_words(&format!("coordinator/sw workers={workers}"), &cfg, n, || {
            let res = h.stem_stream(&words).expect("stream");
            std::hint::black_box(res.len());
        });
        println!("{r}");
        coord.shutdown();
    }

    // Runtime backend end-to-end (the full three-layer path).
    if ama::runtime::default_artifacts_dir().join("stemmer_b256.hlo.txt").exists() {
        let coord = Coordinator::start(
            CoordinatorConfig {
                max_batch: 256,
                max_wait: Duration::from_micros(500),
                queue_capacity: 8192,
                workers: 1,
            },
            runtime_factory(roots.clone()),
        );
        let h = coord.handle();
        let r = bench_words("coordinator/runtime max_batch=256", &cfg, n, || {
            let res = h.stem_stream(&words).expect("stream");
            std::hint::black_box(res.len());
        });
        println!("{r}  (mean batch {:.1})", coord.metrics().mean_batch_size());
        let snap = coord.metrics().snapshot();
        println!("  latency p50 {}us p99 {}us", snap.p50_us, snap.p99_us);
        coord.shutdown();
    } else {
        println!("(skipping runtime backend — run `make artifacts` or `ama emit-hlo`)");
    }
}
