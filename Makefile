# Build entry points referenced throughout the code and docs.
#
#   make data       — regenerate the root dictionaries under data/
#   make artifacts  — AOT-lower the JAX stemmer to artifacts/*.hlo.txt
#   make verify     — tier-1 + clippy + bench + loadtest smoke (scripts/verify.sh)
#   make loadtest   — full serving-path comparison (per-word vs pipelined,
#                     32 conns × 5 s) writing measured rows to BENCH_PR2.json

.PHONY: data artifacts verify test loadtest

data:
	cd python && python3 -m compile.gen_roots ../data

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

verify:
	scripts/verify.sh

test:
	cargo test -q

loadtest:
	cargo build --release
	./target/release/ama loadtest --conns 32 --secs 5 --depth 64 \
		--mode both --backend software-par --out BENCH_PR2.json
