# Build entry points referenced throughout the code and docs.
#
#   make data       — regenerate the root dictionaries under data/
#   make artifacts  — AOT-lower the JAX stemmer to artifacts/*.hlo.txt
#   make verify     — tier-1 + clippy + bench + loadtest + protocol smoke
#                     (scripts/verify.sh)
#   make loadtest   — full serving-path comparison (per-word vs pipelined,
#                     32 conns × 5 s) writing measured rows to BENCH_PR2.json
#   make protocol-check — AMA/1 + legacy-line conformance smoke against a
#                     real `ama serve` process (scripts/protocol_check.sh)

.PHONY: data artifacts verify test loadtest protocol-check

data:
	cd python && python3 -m compile.gen_roots ../data

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

verify:
	scripts/verify.sh

test:
	cargo test -q

loadtest:
	cargo build --release
	./target/release/ama loadtest --conns 32 --secs 5 --depth 64 \
		--mode both --backend software-par --out BENCH_PR2.json

protocol-check:
	cargo build --release
	scripts/protocol_check.sh
