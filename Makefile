# Build entry points referenced throughout the code and docs.
#
#   make data       — regenerate the root dictionaries under data/
#   make artifacts  — AOT-lower the stemmer to artifacts/*.hlo.txt
#                     (JAX when importable, else `ama emit-hlo` — the
#                     rust lowerer — so the cycle works offline; note
#                     JAX-lowered artifacts may need `--features pjrt`,
#                     the emit-hlo ones run on the default interpreter)
#   make verify     — tier-1 + clippy + bench + loadtest + protocol smoke
#                     (scripts/verify.sh)
#   make loadtest   — full serving-path comparison (per-word vs pipelined,
#                     32 conns × 5 s) writing measured rows to BENCH_PR2.json
#   make bench-packed — quick packed-kernel + stem-cache comparison rows
#                     (PR 4 acceptance: packed ≥ array, cache warm ≥ off)
#   make bench-simd — quick SIMD-vs-scalar batch kernel comparison
#                     (PR 6 acceptance: simd ≥ 2× packed on AVX2/NEON hosts;
#                     AMA_SIMD=off|scalar|avx2|neon forces the lane path)
#   make protocol-check — AMA/1 + legacy-line conformance smoke against a
#                     real `ama serve` process (scripts/protocol_check.sh)
#   make gateway-loadtest — gateway scaling + chaos run (PR 7): in-process
#                     replica fleet behind `ama gateway`, mixed AMA/1 load,
#                     forced replica kill+restart; writes BENCH_PR7.json
#   make index-bench — corpus-engine run (PR 8): staged pipeline over a
#                     calibrated synthetic corpus → AMAIDX01 snapshot +
#                     accuracy harness, three root searches against it,
#                     and the index rows/accuracy object in BENCH_PR8.json
#   make loadtest-c10k — C10K readiness run (PR 9): 1024 mostly-idle
#                     keepalive conns through the event-loop ingest vs a
#                     32-conn baseline (p99 must stay within 4x, zero
#                     loss/reorder); writes BENCH_PR9.json
#   make chk        — model-check the lock-free core (PR 10): exhaustive
#                     small-bound interleavings of slab/seqlock/queue/
#                     breaker/coalescer under the chk feature
#   make lint-atomics — atomic-ordering lint (PR 10): facade discipline +
#                     `// ord:` justification on every Ordering site
#                     (pure python, no toolchain needed)

.PHONY: data artifacts verify test loadtest bench-packed bench-simd protocol-check gateway-loadtest index-bench loadtest-c10k chk lint-atomics

data:
	cd python && python3 -m compile.gen_roots ../data

artifacts:
	@if python3 -c "import jax" >/dev/null 2>&1; then \
		cd python && python3 -m compile.aot --out-dir ../artifacts; \
	else \
		echo "jax not importable — falling back to the rust HLO emitter"; \
		cargo build --release && ./target/release/ama emit-hlo --out artifacts; \
	fi

verify:
	scripts/verify.sh

test:
	cargo test -q

loadtest:
	cargo build --release
	./target/release/ama loadtest --conns 32 --secs 5 --depth 64 \
		--mode both --backend software-par --out BENCH_PR2.json

bench-packed:
	cargo build --release
	AMA_BENCH_FAST=1 ./target/release/ama bench json --pr 4 \
		--out /tmp/ama_bench_packed.json
	grep -q 'stem_batch_packed' /tmp/ama_bench_packed.json
	grep -q 'registry_cache_warm' /tmp/ama_bench_packed.json
	grep -q 'speedup_packed_vs_array' /tmp/ama_bench_packed.json

bench-simd:
	cargo build --release
	AMA_BENCH_FAST=1 ./target/release/ama bench json --pr 6 \
		--out /tmp/ama_bench_simd.json
	grep -q 'stem_batch_simd' /tmp/ama_bench_simd.json
	grep -q 'speedup_simd_vs_packed' /tmp/ama_bench_simd.json
	grep -q 'pct_of_hw_model_wps' /tmp/ama_bench_simd.json

protocol-check:
	cargo build --release
	scripts/protocol_check.sh

gateway-loadtest:
	cargo build --release
	./target/release/ama gateway-loadtest --replicas 3 --conns 16 --secs 4 \
		--depth 8 --chaos --out BENCH_PR7.json
	grep -q '"schema": "ama-gateway-v1"' BENCH_PR7.json

loadtest-c10k:
	cargo build --release
	./target/release/ama loadtest --conns 1024 --idle-frac 0.95 --secs 5 \
		--depth 64 --out BENCH_PR9.json
	grep -q '"schema": "ama-loadtest-v1"' BENCH_PR9.json
	grep -q 'p99_flat_ratio_vs_32' BENCH_PR9.json

chk:
	cargo test --features chk --test chk_models

lint-atomics:
	python3 scripts/lint_atomics.py
	python3 scripts/lint_atomics.py --self-test

index-bench:
	cargo build --release
	./target/release/ama index corpus:small:20000 --seed 9 --out /tmp/ama_index_bench.idx
	./target/release/ama search /tmp/ama_index_bench.idx درس --top 5
	./target/release/ama search /tmp/ama_index_bench.idx قال --top 5
	./target/release/ama search /tmp/ama_index_bench.idx درس قال --top 5
	AMA_BENCH_FAST=1 ./target/release/ama bench json --pr 8 --out BENCH_PR8.json
	grep -q 'index/pipeline_build' BENCH_PR8.json
	grep -q 'index/search' BENCH_PR8.json
	grep -q '"accuracy"' BENCH_PR8.json
