# Build entry points referenced throughout the code and docs.
#
#   make data       — regenerate the root dictionaries under data/
#   make artifacts  — AOT-lower the JAX stemmer to artifacts/*.hlo.txt
#   make verify     — tier-1 + clippy + bench smoke (scripts/verify.sh)

.PHONY: data artifacts verify test

data:
	cd python && python3 -m compile.gen_roots ../data

artifacts:
	cd python && python3 -m compile.aot --out-dir ../artifacts

verify:
	scripts/verify.sh

test:
	cargo test -q
