//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build image has no crates.io access, so this path dependency
//! provides the subset of the real crate's surface that `ama` uses:
//!
//! * [`Error`] — an opaque error value carrying a message plus a chain of
//!   context messages; `{:#}` formatting prints `outer: inner: …` like the
//!   real crate's alternate Display.
//! * [`Result<T>`] — alias with `Error` as the default error type.
//! * [`Context`] — `.context(..)` / `.with_context(|| ..)` on `Result`
//!   (any error convertible into [`Error`]) and on `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Swap back to the real crate by replacing the `[dependencies]` entry —
//! no source changes are needed.

use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a message plus an optional chain of causes.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// The innermost error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(next) = cur.source.as_deref() {
            cur = next;
        }
        cur
    }

    /// Iterate the chain outermost-first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }
}

/// Iterator over an error chain (outermost context first).
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            f.write_str("\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

// Like the real anyhow: any std error converts into `Error`, preserving
// its source chain as context layers. `Error` itself deliberately does NOT
// implement `std::error::Error`, which is what keeps this blanket impl
// coherent next to the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut messages = Vec::new();
        let mut cur: Option<&dyn std::error::Error> = Some(&e);
        while let Some(c) = cur {
            messages.push(c.to_string());
            cur = c.source();
        }
        let mut err: Option<Error> = None;
        for msg in messages.into_iter().rev() {
            err = Some(match err {
                None => Error { msg, source: None },
                Some(inner) => Error { msg, source: Some(Box::new(inner)) },
            });
        }
        err.expect("at least one message")
    }
}

/// Context extension for `Result` and `Option`, like `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn context_on_result() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: file missing");
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("op {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "op 7: file missing");
    }

    #[test]
    fn context_on_option() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "file missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let s = String::from("plain");
        assert_eq!(format!("{}", anyhow!(s)), "plain");
    }

    #[test]
    fn chain_walks_outermost_first() {
        let e = Error::msg("inner").context("mid").context("outer");
        let msgs: Vec<String> = e.chain().map(|x| x.msg.clone()).collect();
        assert_eq!(msgs, ["outer", "mid", "inner"]);
        assert_eq!(format!("{}", e.root_cause()), "inner");
    }
}
