"""Pallas one-hot-matmul dictionary matcher vs pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import alphabet as ab
from compile.kernels.match import match
from compile.kernels.ref import ref_match

LETTERS = [c for c in range(0x0621, 0x064B) if c <= 0x063A or c >= 0x0641]


def random_case(rng, m, r, length, hit_rate=0.5):
    roots = np.zeros((r, length), np.int32)
    n_real = max(1, int(r * 0.7))
    roots[:n_real] = rng.choice(LETTERS, size=(n_real, length))
    stems = rng.choice(LETTERS, size=(m, length)).astype(np.int32)
    # plant guaranteed hits
    for i in range(m):
        if rng.random() < hit_rate:
            stems[i] = roots[rng.integers(0, n_real)]
    return stems, roots


@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([(6, 16, 3), (12, 64, 3), (24, 32, 4), (6, 8, 2)]),
)
@settings(max_examples=30, deadline=None)
def test_kernel_matches_ref_random(seed, shape):
    m, r, length = shape
    rng = np.random.default_rng(seed)
    stems, roots = random_case(rng, m, r, length)
    got = np.asarray(match(stems, roots)) != 0
    want = np.asarray(ref_match(stems, roots))
    np.testing.assert_array_equal(got, want)


def test_pad_rows_never_match():
    # A stem of all PADs must not match the dictionary padding.
    roots = np.zeros((8, 3), np.int32)
    roots[0] = [ab.DAL, ab.REH, ab.SEEN]
    stems = np.zeros((6, 3), np.int32)
    got = np.asarray(match(stems, roots))
    assert not got.any()


def test_exact_membership():
    roots = np.zeros((4, 3), np.int32)
    roots[0] = [ab.DAL, ab.REH, ab.SEEN]  # درس
    roots[1] = [ab.LAM, ab.AIN, ab.BEH]  # لعب
    stems = np.array(
        [
            [ab.DAL, ab.REH, ab.SEEN],
            [ab.LAM, ab.AIN, ab.BEH],
            [ab.DAL, ab.REH, ab.SEEN + 1],  # off by one codepoint
            [ab.SEEN, ab.REH, ab.DAL],  # reversed
            [ab.DAL, ab.DAL, ab.DAL],
            [ab.LAM, ab.AIN, ab.BEH],
        ],
        np.int32,
    )
    got = np.asarray(match(stems, roots))
    np.testing.assert_array_equal(got, [1, 1, 0, 0, 0, 1])


def test_multi_tile_accumulation():
    # R larger than one tile: hit lives in the *last* tile; OR-accumulation
    # across grid steps must preserve it (and not clobber earlier hits).
    rng = np.random.default_rng(3)
    m, r, length = 8, 1024, 3
    stems, roots = random_case(rng, m, r, length, hit_rate=0.0)
    roots[r - 1] = stems[0]  # plant a hit in the final row
    got = np.asarray(match(stems, roots, block_r=256)) != 0
    want = np.asarray(ref_match(stems, roots))
    np.testing.assert_array_equal(got, want)
    assert got[0]


def test_block_shape_sweep():
    rng = np.random.default_rng(11)
    stems, roots = random_case(rng, 24, 128, 3)
    want = np.asarray(ref_match(stems, roots))
    for bm, br in [(6, 32), (12, 64), (24, 128), (8, 16)]:
        got = np.asarray(match(stems, roots, block_m=bm, block_r=br)) != 0
        np.testing.assert_array_equal(got, want, err_msg=f"bm={bm} br={br}")


def test_full_dictionary_shapes(dict_arrays):
    # The real artifact shapes: (M,3)x(2048,3), (M,4)x(512,4), (M,2)x(256,2).
    r2, r3, r4 = dict_arrays
    rng = np.random.default_rng(5)
    for roots, length in ((r2, 2), (r3, 3), (r4, 4)):
        stems = rng.choice(LETTERS, size=(12, length)).astype(np.int32)
        stems[0] = roots[0]  # a guaranteed hit
        got = np.asarray(match(stems, roots)) != 0
        want = np.asarray(ref_match(stems, roots))
        np.testing.assert_array_equal(got, want)
        assert got[0]


# --- the direct-mapped lookup kernel (production formulation) --------------

def test_lookup_matches_ref(dictionaries, bitmaps):
    from compile.kernels.lookup import lookup

    bi, tri, quad = dictionaries
    b2, b3, b4 = bitmaps
    rng = np.random.default_rng(17)
    for rows, bm, length in ((bi, b2, 2), (tri, b3, 3), (quad, b4, 4)):
        stems = rng.choice(LETTERS, size=(24, length)).astype(np.int32)
        rows_l = sorted(rows)
        for i in range(0, 24, 3):  # plant hits
            stems[i] = rows_l[int(rng.integers(0, len(rows_l)))]
        got = np.asarray(lookup(stems, bm)) != 0
        want = np.array([tuple(s) in rows for s in stems])
        np.testing.assert_array_equal(got, want)


def test_lookup_pad_stem_misses(bitmaps):
    from compile.kernels.lookup import lookup

    _, b3, _ = bitmaps
    stems = np.zeros((6, 3), np.int32)  # all-PAD → key 0 → miss
    assert not np.asarray(lookup(stems, b3)).any()


def test_lookup_equals_match_modes(dictionaries, bitmaps):
    """All three kernel formulations agree (lookup / compare / matmul)."""
    from compile.kernels.lookup import lookup

    bi, tri, quad = dictionaries
    _, b3, _ = bitmaps
    rng = np.random.default_rng(23)
    stems = rng.choice(LETTERS, size=(48, 3)).astype(np.int32)
    rows_l = sorted(tri)
    for i in range(0, 48, 4):
        stems[i] = rows_l[int(rng.integers(0, len(rows_l)))]
    from compile import alphabet as ab
    roots3 = np.zeros((ab.R3, 3), np.int32)
    for i, row in enumerate(sorted(tri)):
        roots3[i] = row
    a = np.asarray(lookup(stems, b3)) != 0
    b = np.asarray(match(stems, roots3, mode="compare")) != 0
    c = np.asarray(match(stems, roots3, mode="matmul")) != 0
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)
