import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import alphabet as ab  # noqa: E402
from compile import gen_roots  # noqa: E402


@pytest.fixture(scope="session")
def dictionaries():
    """(bi, tri, quad) as python sets of codepoint tuples."""
    bi, tri, quad = gen_roots.build()
    return set(bi), set(tri), set(quad)


def pad_dict(rows, r, length):
    a = np.zeros((r, length), np.int32)
    for i, t in enumerate(sorted(rows)):
        a[i] = t
    return a


@pytest.fixture(scope="session")
def dict_arrays(dictionaries):
    """(roots2, roots3, roots4) as padded int32 arrays, model-input shaped."""
    bi, tri, quad = dictionaries
    return (
        pad_dict(bi, ab.R2, 2),
        pad_dict(tri, ab.R3, 3),
        pad_dict(quad, ab.R4, 4),
    )


@pytest.fixture(scope="session")
def bitmaps(dictionaries):
    """(bitmap2, bitmap3, bitmap4) int32 arrays — the model inputs."""
    bi, tri, quad = dictionaries
    return (
        np.array(ab.build_bitmap(bi, 2), np.int32),
        np.array(ab.build_bitmap(tri, 3), np.int32),
        np.array(ab.build_bitmap(quad, 4), np.int32),
    )
