"""TPU budget estimates stay within the assumed part (DESIGN §HW-Adaptation)."""

from compile import analysis


def test_all_kernels_fit_vmem():
    for k in analysis.all_budgets():
        assert k.vmem_frac < 0.60, f"{k.name} uses {k.vmem_frac:.0%} of VMEM"


def test_lookup_is_cheapest_compute():
    budgets = {k.name: k for k in analysis.all_budgets()}
    assert (
        budgets["match/lookup (bitmap)"].work_per_batch
        < 1e-3 * budgets["match/matmul (MXU)"].work_per_batch
    )


def test_quad_bitmap_dominates_vmem():
    budgets = {k.name: k for k in analysis.all_budgets()}
    quad = budgets["match/lookup quad (bitmap)"]
    assert quad.vmem_bytes > 7 * 2**20
    assert quad.vmem_frac < 0.5


def test_report_renders(capsys):
    analysis.main()
    out = capsys.readouterr().out
    assert "match/lookup" in out and "%VMEM" in out
