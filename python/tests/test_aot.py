"""AOT pipeline: HLO text emission is deterministic and well-formed."""

import numpy as np

from compile import alphabet as ab
from compile.aot import lower_match_micro, lower_stemmer


def test_stemmer_hlo_text_wellformed():
    text = lower_stemmer(1)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # 5 inputs: words, lengths, roots2, roots3, roots4
    assert f"s32[1,{ab.MAX_WORD}]" in text
    assert f"s32[{ab.BITMAP3}]" in text


def test_stemmer_lowering_deterministic():
    assert lower_stemmer(1) == lower_stemmer(1)


def test_match_micro_wellformed():
    text = lower_match_micro(m=192, r=512, length=3)
    assert text.startswith("HloModule")
    assert "s32[192,3]" in text and "s32[512,3]" in text


def test_no_dynamic_shapes_leak():
    # AOT artifacts must be fully static: no dynamic-dimension markers.
    text = lower_stemmer(32)
    assert "<=“" not in text and "?x" not in text


def test_gen_roots_deterministic(dictionaries):
    from compile.gen_roots import build

    a = build()
    b = build()
    assert a == b
    bi, tri, quad = a
    assert len(bi) + len(tri) + len(quad) == 1767  # paper's Quran root count
    # dictionary invariants: unique, correct lengths, Arabic letters only
    for rows, length in ((bi, 2), (tri, 3), (quad, 4)):
        assert len(set(rows)) == len(rows)
        for t in rows:
            assert len(t) == length
            for c in t:
                assert 0x0621 <= c <= 0x064A and ab.char_index(c) != 0


def test_encode_word_roundtrip_examples():
    codes, n = ab.encode_word("أفاستسقيناكموها")
    assert n == 15
    assert codes[0] == ab.ALEF  # hamza-alef normalized
    codes, n = ab.encode_word("دَرَسَ")
    assert n == 3 and codes[:3] == [ab.DAL, ab.REH, ab.SEEN]
