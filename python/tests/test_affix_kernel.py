"""Pallas affix-mask kernel vs pure-jnp oracle (hypothesis sweeps)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import alphabet as ab
from compile.kernels.affix import affix_masks
from compile.kernels.ref import ref_affix_masks

LETTERS = [c for c in range(0x0621, 0x064B) if c <= 0x063A or c >= 0x0641]


def random_batch(rng, b):
    lengths = rng.integers(0, ab.MAX_WORD + 1, size=b).astype(np.int32)
    words = np.zeros((b, ab.MAX_WORD), np.int32)
    for i, n in enumerate(lengths):
        words[i, :n] = rng.choice(LETTERS, size=n)
    return words, lengths


def assert_matches_ref(words, lengths):
    pk, sk = affix_masks(words, lengths)
    pr, sr = ref_affix_masks(words, lengths)
    np.testing.assert_array_equal(np.asarray(pk) != 0, np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(sk) != 0, np.asarray(sr))


@given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4, 8, 32]))
@settings(max_examples=30, deadline=None)
def test_kernel_matches_ref_random(seed, b):
    rng = np.random.default_rng(seed)
    words, lengths = random_batch(rng, b)
    assert_matches_ref(words, lengths)


def test_empty_words():
    words = np.zeros((4, ab.MAX_WORD), np.int32)
    lengths = np.zeros(4, np.int32)
    pk, sk = affix_masks(words, lengths)
    assert not np.asarray(pk).any()
    assert not np.asarray(sk).any()


def test_all_prefix_letters():
    # A word made entirely of prefix letters: every in-word position masks on.
    w = np.zeros((1, ab.MAX_WORD), np.int32)
    w[0, :7] = [ab.FEH, ab.SEEN, ab.ALEF, ab.LAM, ab.TEH, ab.NOON, ab.YEH]
    n = np.array([7], np.int32)
    pk, _ = affix_masks(w, n)
    assert np.asarray(pk)[0, :5].all()


def test_mask_stops_at_length():
    # Characters beyond `len` are "U" registers — never masked on.
    w = np.full((1, ab.MAX_WORD), ab.WAW, np.int32)  # waw is a suffix letter
    n = np.array([3], np.int32)
    _, sk = affix_masks(w, n)
    sk = np.asarray(sk)
    assert sk[0, :3].all() and not sk[0, 3:].any()


def test_nonletter_codes_never_match():
    w = np.full((2, ab.MAX_WORD), 0x0041, np.int32)  # latin 'A'
    n = np.full(2, ab.MAX_WORD, np.int32)
    pk, sk = affix_masks(w, n)
    assert not np.asarray(pk).any() and not np.asarray(sk).any()


def test_block_divisibility_sweep():
    rng = np.random.default_rng(7)
    for b, tb in [(8, 4), (8, 8), (16, 4)]:
        words, lengths = random_batch(rng, b)
        pk, sk = affix_masks(words, lengths, block_b=tb)
        pr, sr = ref_affix_masks(words, lengths)
        np.testing.assert_array_equal(np.asarray(pk) != 0, np.asarray(pr))
        np.testing.assert_array_equal(np.asarray(sk) != 0, np.asarray(sr))
