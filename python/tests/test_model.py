"""Full JAX stemmer graph vs the sequential single-word oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import alphabet as ab
from compile.kernels.ref import ref_stem_word
from compile.model import stem_batch

LETTERS = [c for c in range(0x0621, 0x064B) if c <= 0x063A or c >= 0x0641]


def encode_batch(words):
    enc = [ab.encode_word(w) for w in words]
    return (
        np.array([e[0] for e in enc], np.int32),
        np.array([e[1] for e in enc], np.int32),
    )


def run_both(words_arr, lengths, dictionaries, bitmaps):
    bi, tri, quad = dictionaries
    b2, b3, b4 = bitmaps
    root, kind, cut = stem_batch(words_arr, lengths, b2, b3, b4)
    got = list(zip(map(tuple, np.asarray(root)), np.asarray(kind), np.asarray(cut)))
    want = [
        ref_stem_word(list(w), int(n), bi, tri, quad)
        for w, n in zip(words_arr, lengths)
    ]
    return got, want


# --- the paper's own examples ----------------------------------------------

def test_paper_examples(dictionaries, bitmaps):
    cases = {
        "سيلعبون": ("لعب", ab.KIND_TRI),  # §3.1
        "أفاستسقيناكموها": ("سقي", ab.KIND_TRI),  # §3.1 / Fig 13
        "فتزحزحت": ("زحزح", ab.KIND_QUAD),  # Fig 14
        "قال": ("قول", ab.KIND_RESTORED),  # §6.3 hollow verb
        "يدرسون": ("درس", ab.KIND_TRI),  # Table 1
        "يدرس": ("درس", ab.KIND_TRI),  # Table 1
        "كاتب": ("كتب", ab.KIND_RMINFIX_TRI),  # §6.3 remove infix
    }
    words_arr, lengths = encode_batch(list(cases))
    b2, b3, b4 = bitmaps
    root, kind, _ = stem_batch(words_arr, lengths, b2, b3, b4)
    for i, (w, (exp_root, exp_kind)) in enumerate(cases.items()):
        got = "".join(chr(c) for c in np.asarray(root)[i] if c)
        assert got == exp_root, f"{w}: got {got!r}, want {exp_root!r}"
        assert int(np.asarray(kind)[i]) == exp_kind, f"{w}: kind"


def test_unknown_word_returns_none(dictionaries, bitmaps):
    words_arr, lengths = encode_batch(["ظظظظظ"])
    b2, b3, b4 = bitmaps
    root, kind, _ = stem_batch(words_arr, lengths, b2, b3, b4)
    assert int(np.asarray(kind)[0]) == ab.KIND_NONE
    assert not np.asarray(root)[0].any()


# --- agreement with the sequential oracle ----------------------------------

@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_model_matches_oracle_random(seed, dictionaries, bitmaps):
    rng = np.random.default_rng(seed)
    b = 6
    lengths = rng.integers(2, ab.MAX_WORD + 1, size=b).astype(np.int32)
    words = np.zeros((b, ab.MAX_WORD), np.int32)
    for i, n in enumerate(lengths):
        words[i, :n] = rng.choice(LETTERS, size=n)
    got, want = run_both(words, lengths, dictionaries, bitmaps)
    assert got == want


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_model_matches_oracle_inflected(seed, dictionaries, bitmaps):
    """Adversarial-ish: real roots wrapped in real prefixes/suffixes."""
    rng = np.random.default_rng(seed)
    bi, tri, quad = dictionaries
    tri_list = sorted(tri)
    prefixes = ["", "ي", "ست", "فسي", "ال", "لن"]
    suffixes = ["", "ون", "ها", "تم", "ناكموها", "ة"]
    words = []
    for _ in range(6):
        root = "".join(chr(c) for c in tri_list[rng.integers(0, len(tri_list))])
        w = (
            prefixes[rng.integers(0, len(prefixes))]
            + root
            + suffixes[rng.integers(0, len(suffixes))]
        )
        words.append(w)
    words_arr, lengths = encode_batch(words)
    got, want = run_both(words_arr, lengths, dictionaries, bitmaps)
    assert got == want


def test_short_and_degenerate_words(dictionaries, bitmaps):
    words_arr = np.zeros((6, ab.MAX_WORD), np.int32)
    lengths = np.array([0, 1, 2, 3, 2, 1], np.int32)
    words_arr[1, :1] = [ab.BEH]
    words_arr[2, :2] = [ab.MEEM, ab.DAL]  # مد — bilateral root, but no
    # direct bilateral matching exists: kind must be NONE (bi roots are only
    # reachable through Remove Infix on trilateral stems).
    words_arr[3, :3] = [ab.DAL, ab.REH, ab.SEEN]  # درس exact root
    words_arr[4, :2] = [ab.YEH, ab.TEH]  # all prefix letters
    words_arr[5, :1] = [ab.WAW]
    got, want = run_both(words_arr, lengths, dictionaries, bitmaps)
    assert got == want
    assert want[3][1] == ab.KIND_TRI


def test_batch_one_matches_batch_many(dictionaries, bitmaps):
    """Batch size must not change per-word results."""
    words = ["سيلعبون", "قال", "فتزحزحت", "ظظظظ"]
    words_arr, lengths = encode_batch(words)
    b2, b3, b4 = bitmaps
    root_b, kind_b, cut_b = stem_batch(words_arr, lengths, b2, b3, b4)
    for i in range(len(words)):
        r1, k1, c1 = stem_batch(words_arr[i : i + 1], lengths[i : i + 1], b2, b3, b4)
        np.testing.assert_array_equal(np.asarray(r1)[0], np.asarray(root_b)[i])
        assert int(np.asarray(k1)[0]) == int(np.asarray(kind_b)[i])
        assert int(np.asarray(c1)[0]) == int(np.asarray(cut_b)[i])
