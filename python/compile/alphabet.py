"""Shared Arabic alphabet constants for the L1/L2 build path.

Single source of truth on the python side; must agree exactly with
``rust/src/chars.rs`` (the rust test-suite cross-checks the generated
artifacts against these semantics).

The paper (Damaj et al., §3.1, §5.2) processes 16-bit Arabic Unicode,
strips diacritics, ignores the hamza-alef distinction, and fixes the
datapath at 15 characters.
"""

# --- geometry (paper's register file) -------------------------------------
MAX_WORD = 15      # longest Arabic word: أفاستسقيناكموها
MAX_PREFIX = 5     # 5 prefix registers in the datapath
MAX_SUFFIX = 9     # at most 9 suffix characters
NUM_CUTS = 6       # prefix cut index p ∈ 0..=5
PAD = 0

# --- codepoints ------------------------------------------------------------
HAMZA = 0x0621
ALEF_MADDA = 0x0622
ALEF_HAMZA_ABOVE = 0x0623
WAW_HAMZA = 0x0624
ALEF_HAMZA_BELOW = 0x0625
YEH_HAMZA = 0x0626
ALEF = 0x0627
BEH = 0x0628
TEH_MARBUTA = 0x0629
TEH = 0x062A
THEH = 0x062B
JEEM = 0x062C
HAH = 0x062D
KHAH = 0x062E
DAL = 0x062F
THAL = 0x0630
REH = 0x0631
ZAIN = 0x0632
SEEN = 0x0633
SHEEN = 0x0634
SAD = 0x0635
DAD = 0x0636
TAH = 0x0637
ZAH = 0x0638
AIN = 0x0639
GHAIN = 0x063A
FEH = 0x0641
QAF = 0x0642
KAF = 0x0643
LAM = 0x0644
MEEM = 0x0645
NOON = 0x0646
HEH = 0x0647
WAW = 0x0648
ALEF_MAKSURA = 0x0649
YEH = 0x064A

# The seven prefix letters (فسألتني), Fig. 3's VHDL constant — plus bare
# ALEF because normalization collapses أ→ا before the datapath sees it.
PREFIX_LETTERS = (ALEF_HAMZA_ABOVE, TEH, SEEN, FEH, LAM, NOON, YEH, ALEF)

# The nine suffix letters (covers every suffix in the paper's examples).
SUFFIX_LETTERS = (ALEF, TEH, HEH, KAF, MEEM, WAW, NOON, YEH, TEH_MARBUTA)

# The five infix letters (focus on the vowels ا و ي).
INFIX_LETTERS = (ALEF, WAW, YEH, TEH, NOON)

# --- dense alphabet for the one-hot matcher --------------------------------
ALPHABET_SIZE = 37  # 36 letters + PAD(0)


def char_index(c: int) -> int:
    """Dense index 1..=36 for Arabic letters, 0 for PAD/other.

    Mirrors ``chars::char_index`` in rust.
    """
    if 0x0621 <= c <= 0x063A:
        return c - 0x0621 + 1
    if 0x0641 <= c <= 0x064A:
        return c - 0x0641 + 27
    return 0


def index_char(i: int) -> int:
    if 1 <= i <= 26:
        return 0x0621 + i - 1
    if 27 <= i <= 36:
        return 0x0641 + i - 27
    return PAD


def normalize_char(c: int) -> int:
    """Hamza-carrier alefs → bare alef; alef maksura → yeh."""
    if c in (ALEF_MADDA, ALEF_HAMZA_ABOVE, ALEF_HAMZA_BELOW):
        return ALEF
    if c == ALEF_MAKSURA:
        return YEH
    return c


def is_diacritic(c: int) -> bool:
    return 0x064B <= c <= 0x0652 or c == 0x0670


def encode_word(s: str) -> tuple[list[int], int]:
    """String → (15 padded codepoints, length); mirrors ArabicWord::encode."""
    out = []
    for ch in s:
        c = ord(ch)
        if c > 0xFFFF or is_diacritic(c) or c == 0x0640:
            continue
        out.append(normalize_char(c))
        if len(out) == MAX_WORD:
            break
    codes = out + [PAD] * (MAX_WORD - len(out))
    return codes, len(out)


# --- dictionary geometry (runtime-input shapes) -----------------------------
R2, R3, R4 = 256, 2048, 512

# --- match kinds (model output flag) ----------------------------------------
KIND_NONE = 0
KIND_TRI = 1          # direct trilateral match
KIND_QUAD = 2         # direct quadrilateral match
KIND_RMINFIX_TRI = 3  # quad stem, infix 2nd char removed → trilateral root
KIND_RMINFIX_BI = 4   # tri stem, infix 2nd char removed → bilateral root
KIND_RESTORED = 5     # tri stem, 2nd char ا→و (hollow verb) → trilateral

# --- direct-mapped dictionary bitmaps (the block-RAM lookup formulation) ---
# key(stem) = Σ char_index(c_k)·37^(L-1-k); bitmap[key] == 1 iff root.
BITMAP2 = ALPHABET_SIZE**2   # 1,369
BITMAP3 = ALPHABET_SIZE**3   # 50,653
BITMAP4 = ALPHABET_SIZE**4   # 1,874,161


def stem_key(codes) -> int:
    """Polynomial key of a stem (python reference for tests)."""
    k = 0
    for c in codes:
        k = k * ALPHABET_SIZE + char_index(c)
    return k


def build_bitmap(roots, length) -> "list[int]":
    """Dense membership bitmap for a root set (tuples of codepoints)."""
    bm = [0] * (ALPHABET_SIZE**length)
    for r in roots:
        assert len(r) == length
        bm[stem_key(r)] = 1
    return bm
