"""Pure-jnp / pure-python oracles for the Pallas kernels and the model.

Three levels of reference:

* :func:`ref_affix_masks` — jnp oracle for ``kernels.affix``.
* :func:`ref_match` — jnp oracle for ``kernels.match`` (dictionary
  membership).
* :func:`ref_stem_word` — plain-python single-word implementation of the
  complete paper algorithm (candidate enumeration + dictionary compare +
  both infix algorithms). This is the ground truth the JAX model, the rust
  software stemmer and the rust HW simulator must all agree with.
"""

import jax.numpy as jnp

from .. import alphabet as ab


# --------------------------------------------------------------------------
# jnp oracles
# --------------------------------------------------------------------------

def ref_affix_masks(words, lengths):
    """Prefix/suffix letter masks, the parallel comparator array of Fig. 6/7.

    words: (B, 15) int32, lengths: (B,) int32.
    Returns (pmask (B,5) bool, smask (B,15) bool); positions >= len are
    False in both (they are "U" registers in the paper's datapath).
    """
    words = jnp.asarray(words, jnp.int32)
    pos = jnp.arange(ab.MAX_WORD, dtype=jnp.int32)[None, :]
    in_word = pos < jnp.asarray(lengths, jnp.int32)[:, None]
    p = jnp.zeros_like(words, dtype=bool)
    for c in ab.PREFIX_LETTERS:
        p = p | (words == c)
    s = jnp.zeros_like(words, dtype=bool)
    for c in ab.SUFFIX_LETTERS:
        s = s | (words == c)
    return (p & in_word)[:, : ab.MAX_PREFIX], s & in_word


def ref_match(stems, roots):
    """Dictionary membership: stems (..., L) int32 vs roots (R, L) int32.

    Returns (...,) bool — True iff the stem equals some non-pad root row.
    A root row is pad iff its first character is PAD.
    """
    stems = jnp.asarray(stems, jnp.int32)
    roots = jnp.asarray(roots, jnp.int32)
    real = roots[:, 0] != ab.PAD  # (R,)
    eq = (stems[..., None, :] == roots[None, ...]).all(-1)  # (..., R)
    return (eq & real).any(-1)


# --------------------------------------------------------------------------
# candidate enumeration (shared between oracle and model)
# --------------------------------------------------------------------------

def candidate_valid(codes, n, p, size):
    """Is the window word[p:p+size] a valid stem candidate?

    Shared semantics (DESIGN.md §6): the p prefix characters must all be
    prefix letters, the trailing n-(p+size) characters must all be suffix
    letters and number at most MAX_SUFFIX.
    """
    if p + size > n:
        return False
    if n - (p + size) > ab.MAX_SUFFIX:
        return False
    if any(codes[i] not in ab.PREFIX_LETTERS for i in range(p)):
        return False
    if any(codes[j] not in ab.SUFFIX_LETTERS for j in range(p + size, n)):
        return False
    return True


def ref_stem_word(codes, n, roots2, roots3, roots4):
    """Full single-word oracle. codes: list of 15 ints; n: length.

    roots*: python sets of tuples.
    Returns (root_tuple_padded_to_4, kind, p).
    """
    # Pass 1/2: direct trilateral then quadrilateral (paper Fig. 4), by
    # ascending prefix cut.
    for size, kind, dic in ((3, ab.KIND_TRI, roots3), (4, ab.KIND_QUAD, roots4)):
        for p in range(ab.NUM_CUTS):
            if candidate_valid(codes, n, p, size):
                stem = tuple(codes[p : p + size])
                if stem in dic:
                    return stem + (ab.PAD,) * (4 - size), kind, p
    # Pass 3: Remove Infix on quadrilateral stems → trilateral roots.
    for p in range(ab.NUM_CUTS):
        if candidate_valid(codes, n, p, 4):
            stem = codes[p : p + 4]
            if stem[1] in ab.INFIX_LETTERS:
                red = (stem[0], stem[2], stem[3])
                if red in roots3:
                    return red + (ab.PAD,), ab.KIND_RMINFIX_TRI, p
    # Pass 4: Remove Infix on trilateral stems → bilateral roots.
    for p in range(ab.NUM_CUTS):
        if candidate_valid(codes, n, p, 3):
            stem = codes[p : p + 3]
            if stem[1] in ab.INFIX_LETTERS:
                red = (stem[0], stem[2])
                if red in roots2:
                    return red + (ab.PAD, ab.PAD), ab.KIND_RMINFIX_BI, p
    # Pass 5: Restore Original Form (hollow verbs): 2nd char ا → و.
    for p in range(ab.NUM_CUTS):
        if candidate_valid(codes, n, p, 3):
            stem = codes[p : p + 3]
            if stem[1] == ab.ALEF:
                res = (stem[0], ab.WAW, stem[2])
                if res in roots3:
                    return res + (ab.PAD,), ab.KIND_RESTORED, p
    return (ab.PAD,) * 4, ab.KIND_NONE, 0
