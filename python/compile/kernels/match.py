"""L1 Pallas kernel: one-hot-matmul dictionary matcher (paper Fig. 8).

The paper replicates ``stem3_Comparator``/``stem4_Comparator`` instances to
compare generated stems against the stored root lists in parallel. Exact
string equality against R dictionary rows is re-thought for the MXU:

    onehot(stem) · onehot(root)ᵀ  ==  L      ⇔      stem == root

so membership over the whole dictionary becomes one
``(TM, L·37) × (L·37, TR)`` matmul per tile — systolic-array work instead of
R sequential comparators. The dictionary panel is the stationary operand
(the analog of the paper's roots in FPGA block RAM); BlockSpec streams
stem tiles HBM→VMEM against it, accumulating an OR across dictionary tiles.

interpret=True for CPU-PJRT execution; see DESIGN.md §Hardware-Adaptation
for the VMEM/MXU budget on real hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import alphabet as ab


def _dense_index(c):
    """Codepoint → dense alphabet index 1..36 (0 for PAD), vectorized.

    Mirrors ``alphabet.char_index`` / ``chars::char_index``.
    """
    lo = jnp.logical_and(c >= 0x0621, c <= 0x063A)
    hi = jnp.logical_and(c >= 0x0641, c <= 0x064A)
    return jnp.where(lo, c - 0x0621 + 1, jnp.where(hi, c - 0x0641 + 27, 0))


def _onehot_flat(x, length):
    """(T, L) int32 codepoints → (T, L*37) f32 one-hot block."""
    idx = _dense_index(x)
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, ab.ALPHABET_SIZE), 2)
    oh = (idx[:, :, None] == iota).astype(jnp.float32)
    return oh.reshape(x.shape[0], length * ab.ALPHABET_SIZE)


def _match_kernel_matmul(length, stems_ref, roots_ref, out_ref):
    """MXU formulation: one-hot inner product == L ⇔ exact match."""
    j = pl.program_id(1)
    s_oh = _onehot_flat(stems_ref[...], length)  # (TM, L*37)
    r = roots_ref[...]  # (TR, L)
    r_oh = _onehot_flat(r, length)  # (TR, L*37)
    # MXU tile: #agreeing characters for every (stem, root) pair.
    score = jnp.dot(s_oh, r_oh.T, preferred_element_type=jnp.float32)
    real = (r[:, 0] != ab.PAD)[None, :]  # pad dictionary rows never match
    hit = jnp.logical_and(score == float(length), real).any(axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = jnp.logical_or(out_ref[...] != 0, hit).astype(jnp.int32)


def _match_kernel_compare(length, stems_ref, roots_ref, out_ref):
    """VPU formulation: broadcast integer equality + AND/OR reductions.

    On CPU (and for small L) this does L·TM·TR integer compares instead of
    the matmul's 2·TM·TR·L·37 MACs — a ~25× FLOP reduction that the §Perf
    pass measured as the difference between 5.7 kWps and >100 kWps end to
    end. The matmul variant remains the documented TPU/MXU target.
    """
    del length
    j = pl.program_id(1)
    s = stems_ref[...]  # (TM, L)
    r = roots_ref[...]  # (TR, L)
    eq = (s[:, None, :] == r[None, :, :]).all(axis=-1)  # (TM, TR)
    real = (r[:, 0] != ab.PAD)[None, :]
    hit = jnp.logical_and(eq, real).any(axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = jnp.logical_or(out_ref[...] != 0, hit).astype(jnp.int32)


_KERNELS = {"matmul": _match_kernel_matmul, "compare": _match_kernel_compare}


@functools.partial(jax.jit, static_argnames=("block_m", "block_r", "mode"))
def match(stems, roots, block_m: int = 0, block_r: int = 0, mode: str = "compare"):
    """Dictionary membership for a flat batch of fixed-length stems.

    stems: (M, L) int32; roots: (R, L) int32 (0-padded rows ignored).
    Returns (M,) int32 — 1 iff the stem is a dictionary root.

    mode: "compare" (VPU equality — default, fastest on CPU) or "matmul"
    (the one-hot MXU formulation — the TPU target; see module docs).
    """
    m, length = stems.shape
    r, rl = roots.shape
    assert rl == length, f"stem length {length} != root length {rl}"
    tm = block_m or (m if m <= 1536 else 1536)
    tr = block_r or (r if r <= 2048 else 2048)
    assert m % tm == 0, f"M={m} not divisible by TM={tm}"
    assert r % tr == 0, f"R={r} not divisible by TR={tr}"
    grid = (m // tm, r // tr)
    return pl.pallas_call(
        functools.partial(_KERNELS[mode], length),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, length), lambda i, j: (i, 0)),
            pl.BlockSpec((tr, length), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=True,
    )(jnp.asarray(stems, jnp.int32), jnp.asarray(roots, jnp.int32))
