"""L1 Pallas kernel: the parallel affix-comparator array (paper Figs 6–7).

The paper replicates ``checkPrefix`` seven-way over each of the first five
characters and ``checkSuffix`` over all fifteen characters — 20 spatial
comparator instances on the FPGA. The TPU re-expression is a vector-parallel
membership test over a whole batch tile held in VMEM: one grid step does
what the FPGA did for one word in one clock, for ``TB`` words at once.

Always lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls); on a real TPU the same kernel tiles (TB, 15) int32 panels
through VMEM and runs entirely on the VPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import alphabet as ab


def _affix_kernel(words_ref, lengths_ref, pmask_ref, smask_ref):
    w = words_ref[...]  # (TB, 15) int32
    n = lengths_ref[...]  # (TB,) int32
    pos = jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    in_word = pos < n[:, None]

    p = jnp.zeros(w.shape, jnp.bool_)
    for c in ab.PREFIX_LETTERS:  # 7(+1 normalized-alef) parallel comparators
        p = p | (w == c)
    s = jnp.zeros(w.shape, jnp.bool_)
    for c in ab.SUFFIX_LETTERS:  # 9 parallel comparators
        s = s | (w == c)

    pmask_ref[...] = (p & in_word)[:, : ab.MAX_PREFIX].astype(jnp.int32)
    smask_ref[...] = (s & in_word).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b",))
def affix_masks(words, lengths, block_b: int = 0):
    """Prefix/suffix masks for a batch.

    words: (B, 15) int32; lengths: (B,) int32.
    Returns (pmask (B, 5) int32, smask (B, 15) int32).
    """
    b = words.shape[0]
    tb = block_b or (b if b <= 256 else 256)
    assert b % tb == 0, f"batch {b} not divisible by block {tb}"
    grid = (b // tb,)
    return pl.pallas_call(
        _affix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, ab.MAX_WORD), lambda i: (i, 0)),
            pl.BlockSpec((tb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tb, ab.MAX_PREFIX), lambda i: (i, 0)),
            pl.BlockSpec((tb, ab.MAX_WORD), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, ab.MAX_PREFIX), jnp.int32),
            jax.ShapeDtypeStruct((b, ab.MAX_WORD), jnp.int32),
        ],
        interpret=True,
    )(jnp.asarray(words, jnp.int32), jnp.asarray(lengths, jnp.int32))
