"""L1 Pallas kernel: direct-mapped dictionary lookup.

The third formulation of the paper's Fig-8 comparator array, and the one
closest to what an FPGA engineer would actually synthesize: the root store
as a *block RAM* addressed by the stem itself. Each stem maps to a
polynomial key over the dense 37-symbol alphabet
(``key = ((i₁·37)+i₂)·37+i₃``) and membership is one gather from a dense
bitmap — O(1) per stem instead of the O(R) comparator scan.

Picked as the production formulation by the §Perf pass (EXPERIMENTS.md):
on CPU it replaced ~28M integer compares per 256-word batch with ~4.6k
gathers. On TPU the tri bitmap (50,653 × i32 ≈ 200 KB) sits comfortably in
VMEM; the quad bitmap (1.87M × i32 ≈ 7.5 MB) fits modern VMEM but would be
tiled or swapped for the `match` compare/matmul kernels on older parts —
see DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import alphabet as ab
from .match import _dense_index


def _lookup_kernel(length, stems_ref, bitmap_ref, out_ref):
    idx = _dense_index(stems_ref[...])  # (TM, L)
    key = idx[:, 0]
    for k in range(1, length):
        key = key * ab.ALPHABET_SIZE + idx[:, k]
    bm = bitmap_ref[...]
    out_ref[...] = bm[key].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m",))
def lookup(stems, bitmap, block_m: int = 0):
    """Dictionary membership via the direct-mapped bitmap.

    stems: (M, L) int32 codepoints; bitmap: (37**L,) int32 0/1.
    Returns (M,) int32 — 1 iff the stem is a dictionary root.
    """
    m, length = stems.shape
    assert bitmap.shape == (ab.ALPHABET_SIZE**length,), bitmap.shape
    tm = block_m or m  # whole batch per tile; gathers are cheap
    assert m % tm == 0, f"M={m} not divisible by TM={tm}"
    return pl.pallas_call(
        functools.partial(_lookup_kernel, length),
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, length), lambda i: (i, 0)),
            pl.BlockSpec(bitmap.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=True,
    )(jnp.asarray(stems, jnp.int32), jnp.asarray(bitmap, jnp.int32))
