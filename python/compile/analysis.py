"""L1 performance analysis: VMEM footprint + MXU/VPU utilization estimates
for the three dictionary-match kernel formulations on a real TPU part.

interpret=True gives CPU-numpy timings only, so real-TPU performance is
*estimated* from the BlockSpec geometry (DESIGN.md §Hardware-Adaptation and
EXPERIMENTS.md §Perf reference this module). Run as::

    python -m compile.analysis

and it prints the per-kernel budget table; ``pytest`` checks the budgets
stay within the part's VMEM.
"""

from dataclasses import dataclass

from . import alphabet as ab

#: TPU v4-lite-class budget assumed for estimates.
VMEM_BYTES = 16 * 2**20  # 16 MiB per core
MXU_FLOPS = 137e12       # bf16 peak
VPU_OPS = 4.3e12         # elementwise int32 ops/s (order of magnitude)
HBM_BW = 6.15e11         # 615 GB/s


@dataclass
class KernelBudget:
    name: str
    vmem_bytes: int
    work_per_batch: float  # FLOPs or int-ops for one B=256 stemmer batch
    unit: str
    est_batch_us: float

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BYTES


def matmul_budget(b: int = 256) -> KernelBudget:
    """One-hot MXU matmul over the fused tri stream (the dominant call)."""
    m = b * 18  # fused trilateral streams
    k = 3 * ab.ALPHABET_SIZE  # 111
    r = 2048  # padded tri dictionary
    # TPU tiles (the CPU-interpret build uses 1536x2048, which the VMEM
    # check below would rightly reject — on-part the kernel re-tiles):
    tm, tr = 256, 512
    vmem = 4 * (tm * k + tr * k + tm * tr)  # f32 stationary + tiles
    flops = 2.0 * m * k * r
    return KernelBudget("match/matmul (MXU)", vmem, flops, "FLOP", flops / MXU_FLOPS * 1e6)


def compare_budget(b: int = 256) -> KernelBudget:
    m = b * 18
    r = 2048
    vmem = 4 * (1536 * 3 + r * 3) + 1536 * r  # int32 tiles + bool tile
    ops = float(m * r * 3)
    return KernelBudget("match/compare (VPU)", vmem, ops, "int-op", ops / VPU_OPS * 1e6)


def lookup_budget(b: int = 256) -> KernelBudget:
    m = b * 18
    vmem = 4 * (ab.BITMAP3 + m * 3 + m)  # bitmap resident + keys + out
    ops = float(m * 4)  # key polynomial + gather
    return KernelBudget("match/lookup (bitmap)", vmem, ops, "int-op", ops / VPU_OPS * 1e6)


def quad_lookup_budget(b: int = 256) -> KernelBudget:
    m = b * 6
    vmem = 4 * (ab.BITMAP4 + m * 4 + m)
    ops = float(m * 5)
    return KernelBudget("match/lookup quad (bitmap)", vmem, ops, "int-op", ops / VPU_OPS * 1e6)


def affix_budget(b: int = 256) -> KernelBudget:
    vmem = 4 * (b * ab.MAX_WORD * 2 + b * ab.MAX_PREFIX + b)
    ops = float(b * ab.MAX_WORD * (len(ab.PREFIX_LETTERS) + len(ab.SUFFIX_LETTERS)))
    return KernelBudget("affix masks (VPU)", vmem, ops, "int-op", ops / VPU_OPS * 1e6)


def all_budgets(b: int = 256):
    return [
        affix_budget(b),
        matmul_budget(b),
        compare_budget(b),
        lookup_budget(b),
        quad_lookup_budget(b),
    ]


def main() -> None:
    print(f"TPU estimate (VMEM {VMEM_BYTES >> 20} MiB, MXU {MXU_FLOPS / 1e12:.0f} TFLOPs)")
    print(f"{'kernel':<28} {'VMEM':>10} {'%VMEM':>7} {'work/batch':>14} {'est µs':>8}")
    for k in all_budgets():
        print(
            f"{k.name:<28} {k.vmem_bytes >> 10:>8}KiB {100 * k.vmem_frac:>6.1f}% "
            f"{k.work_per_batch:>11.2e} {k.unit:<3} {k.est_batch_us:>7.2f}"
        )
    print(
        "\nconclusion: lookup kernels are VMEM-bound (tri 198 KiB, quad "
        "7.1 MiB — fits), matmul is the MXU fallback when VMEM is tight."
    )


if __name__ == "__main__":
    main()
