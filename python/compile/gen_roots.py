"""Generate the root dictionaries under ``data/``.

The paper validates stems against "stored Arabic verb roots" (1,767 roots
are extractable from the Holy Quran text). We build the dictionary from:

* a curated list of real, high-frequency Arabic verb roots — including all
  ten Table-7 roots with their Quran frequencies pinned by the corpus
  generator — plus real quadrilaterals and bilaterals (geminated verbs);
* a deterministic synthetic expansion to the paper's 1,767-root count,
  generated with splitmix64 so the file is bit-identical on every run.

One root per line, UTF-8, normalized (hamza-alefs collapsed, no
diacritics). Deterministic: ``make artifacts`` regenerates identical files.
"""

import os

from . import alphabet as ab

# --- real root seed lists ---------------------------------------------------

TRILATERAL = """
كتب درس علم قول كون فعل جعل خلق عمل كفر نزل نفس كذب سقي لعب ذهب شرب سمع بصر
نظر حسب حمل حكم ظلم غفر رحم سجد صبر شكر صدق وعد خرج دخل نصر ضرب قتل رزق خوف
عبد ملك هلك سلم قدر قضي هدي ضلل وقي فتح كسب طلب وجد عرف فهم بلغ تبع جمع فرق
قطع وصل رجع وقف جلس قعد نوم قوم صوم زرع حصد بني هدم رفع خفض وضع اخذ ترك بدا
ختم عود سير طير بيع موت عيش ذكر نسي حفظ كشف ستر ظهر بطن دعو ودد كره غضب رضي
فرح حزن ضحك بكي مشي جري سبح غرق نجو هرب لحق سبق امن شرك وحد عدل صلح فسد نفع
زيد نقص كمل بقي فني دوم زول حيي ولد كبر صغر طول قصر وسع ضيق سهل صعب يسر عسر
قرب بعد جهل حلم عقل جنن مرض شفي طبخ خبز لبس خلع غسل نظف فقر غني ربح خسر تجر
شري دفع قبض بسط مدد شدد ظنن عدد حدد جدد قصص مسس ردد صبب حجج دلل ذلل عزز غرر
قرر مرر ضمم همم حبب تمم حقق حلل خفف درر ذمم سدد شقق صفف نزع خشع خضع طمع قنع
ركع نبا سال جوب حور نور سرج وهج لمع برق رعد مطر ثلج برد حرر سخن دفا روح نفخ
نفث عطس سعل شهق زفر صرخ همس نطق لفظ عبر شرح فصل وجز طنب سهب خطب وعظ نصح غشش
""".split()

BILATERAL = """
مد شد ظن عد حد جد قص مس رد صب حج دل ذل عز غر قر مر ضم هم حب تم حق حل خف در
ذم سد شق صف ضل
""".split()

QUADRILATERAL = """
دحرج زلزل ترجم وسوس بعثر طمان عربد قهقه زحزح حملق دغدغ برهن سيطر هرول بعزق
غرغر ثرثر تمتم همهم لملم كركر قرقر عسعس وشوش خشخش صلصل جلجل حصحص كبكب ذبذب
""".split()

# target counts — paper: 1,767 roots extractable from the Quran text
N_TRI, N_QUAD, N_BI = 1600, 127, 40

# consonant pool for synthetic roots: strong consonants plus a sprinkle of
# affix letters (ت ن س ل ف) so synthetic roots exhibit the same
# prefix/suffix ambiguity real Arabic roots do.
_POOL = [
    ab.BEH, ab.JEEM, ab.HAH, ab.KHAH, ab.DAL, ab.THAL, ab.REH, ab.ZAIN,
    ab.SHEEN, ab.SAD, ab.DAD, ab.TAH, ab.ZAH, ab.AIN, ab.GHAIN, ab.QAF,
    ab.KAF, ab.MEEM, ab.HEH, ab.THEH, ab.TEH, ab.NOON, ab.SEEN, ab.LAM,
    ab.FEH,
]


def _splitmix64(state: int):
    state = (state + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return state, z ^ (z >> 31)


def _norm(word: str) -> tuple:
    codes, n = ab.encode_word(word)
    return tuple(codes[:n])


def _synth(existing: set, count: int, length: int, seed: int) -> list:
    out, state = [], seed
    while len(out) < count:
        state, z = _splitmix64(state)
        cs = []
        for k in range(length):
            cs.append(_POOL[(z >> (8 * k)) % len(_POOL)])
        # no immediate repeats except the classic geminate C1C2C2 shape
        if length >= 2 and cs[0] == cs[1]:
            continue
        if length == 4 and (cs[1] == cs[2] or cs[2] == cs[3]):
            continue
        t = tuple(cs)
        if t in existing:
            continue
        existing.add(t)
        out.append(t)
    return out


def build():
    """Return (bi, tri, quad) lists of codepoint tuples, deterministic."""
    tri = []
    seen = set()
    for w in TRILATERAL:
        t = _norm(w)
        assert len(t) == 3, f"bad trilateral {w!r} -> {t}"
        if t not in seen:
            seen.add(t)
            tri.append(t)
    tri += _synth(seen, N_TRI - len(tri), 3, seed=0x5EED_0003)

    bi, seen2 = [], set()
    for w in BILATERAL:
        t = _norm(w)
        assert len(t) == 2, f"bad bilateral {w!r}"
        if t not in seen2:
            seen2.add(t)
            bi.append(t)
    bi += _synth(seen2, N_BI - len(bi), 2, seed=0x5EED_0002)

    quad, seen4 = [], set()
    for w in QUADRILATERAL:
        t = _norm(w)
        assert len(t) == 4, f"bad quadrilateral {w!r}"
        if t not in seen4:
            seen4.add(t)
            quad.append(t)
    quad += _synth(seen4, N_QUAD - len(quad), 4, seed=0x5EED_0004)

    assert len(tri) == N_TRI and len(quad) == N_QUAD and len(bi) == N_BI
    assert len(tri) <= ab.R3 and len(quad) <= ab.R4 and len(bi) <= ab.R2
    return bi, tri, quad


def write(data_dir: str) -> None:
    os.makedirs(data_dir, exist_ok=True)
    bi, tri, quad = build()
    for name, roots in (
        ("roots_bilateral.txt", bi),
        ("roots_trilateral.txt", tri),
        ("roots_quadrilateral.txt", quad),
    ):
        path = os.path.join(data_dir, name)
        with open(path, "w", encoding="utf-8") as f:
            for t in roots:
                f.write("".join(chr(c) for c in t) + "\n")
        print(f"wrote {path} ({len(roots)} roots)")


if __name__ == "__main__":
    import sys

    write(sys.argv[1] if len(sys.argv) > 1 else "../data")
