"""L2: the complete LB stemmer as a fixed-shape batched JAX graph.

This is the paper's five-stage processor expressed as dataflow:

  stage 1  parallel affix comparators            → kernels.affix (Pallas)
  stage 2  produce prefix/suffix cut validity    → cumulative ANDs (jnp)
  stage 3  generate + filter stems               → static windows (jnp)
  stage 4  compare against stored roots          → kernels.match (Pallas)
  stage 5  extract root (priority select)        → masked argmin (jnp)

plus the paper's two infix algorithms (§6.3) as extra stage-3/4 candidate
streams: *Remove Infix* (2nd char dropped, quad→tri and tri→bi) and
*Restore Original Form* (hollow verbs, 2nd char ا→و).

Everything is static-shape so the graph AOT-lowers to a single HLO module
per batch size; the rust runtime feeds `(words, lengths, roots2, roots3,
roots4)` and reads `(root, kind, cut)` back. Dictionaries are runtime
inputs, so the same artifact serves any dictionary of the agreed shape.
"""

import jax
import jax.numpy as jnp

from . import alphabet as ab
from .kernels.affix import affix_masks
from .kernels.lookup import lookup

#: number of candidate streams (tri, quad, rm-infix-tri, rm-infix-bi, restored)
NUM_STREAMS = 5


def _windows(words, size):
    """Static candidate windows: (B, 6, size) — word[p:p+size] for p ∈ 0..=5."""
    return jnp.stack([words[:, p : p + size] for p in range(ab.NUM_CUTS)], axis=1)


def _validity(pmask, smask, lengths):
    """Candidate validity per (cut p, stem size L) from the affix masks.

    Returns (valid3, valid4): (B, 6) bool each. Mirrors
    ``ref.candidate_valid`` — see DESIGN.md §6 for the shared contract.
    """
    b = pmask.shape[0]
    n = lengths[:, None].astype(jnp.int32)
    # prefix_valid[p]: the first p characters are all prefix letters.
    pv = jnp.concatenate(
        [jnp.ones((b, 1), jnp.int32), jnp.cumprod(pmask, axis=1)], axis=1
    )  # (B, 6)
    # suffix_from[k]: every in-word position j >= k is a suffix letter.
    pos = jnp.arange(ab.MAX_WORD, dtype=jnp.int32)[None, :]
    s_ok = jnp.logical_or(smask != 0, pos >= n).astype(jnp.int32)
    sfrom = jnp.concatenate(
        [
            jnp.flip(jnp.cumprod(jnp.flip(s_ok, axis=1), axis=1), axis=1),
            jnp.ones((b, 1), jnp.int32),
        ],
        axis=1,
    )  # (B, 16); sfrom[:, k] = all suffix-ok from k to end

    def valid(size):
        cut = jnp.arange(ab.NUM_CUTS, dtype=jnp.int32)[None, :]  # p
        end = jnp.broadcast_to(cut + size, (b, ab.NUM_CUTS))
        fits = end <= n
        sfx_len_ok = (n - end) <= ab.MAX_SUFFIX
        sfx_ok = jnp.take_along_axis(sfrom, jnp.minimum(end, ab.MAX_WORD), axis=1)
        return (pv != 0) & fits & sfx_len_ok & (sfx_ok != 0)

    return valid(3), valid(4)


def _match_stream(stems, bitmap):
    """(B, C, L) candidates → (B, C) found, via the Pallas bitmap lookup."""
    b, c, length = stems.shape
    return lookup(stems.reshape(b * c, length), bitmap).reshape(b, c) != 0


def stem_batch(words, lengths, bitmap2, bitmap3, bitmap4):
    """Extract verb roots for a batch of encoded words.

    words: (B, 15) int32; lengths: (B,) int32;
    bitmap2/3/4: (37², )/(37³, )/(37⁴,) int32 direct-mapped dictionary
    bitmaps (``alphabet.build_bitmap``).

    Returns (root (B, 4) int32 0-padded, kind (B,) int32, cut (B,) int32).
    Kind codes in ``alphabet`` (KIND_*); priority = stream order then
    smaller prefix cut, matching the sequential oracle exactly.
    """
    words = jnp.asarray(words, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)

    # --- stages 1–2: affix comparator array + cut validity ---------------
    pmask, smask = affix_masks(words, lengths)
    valid3, valid4 = _validity(pmask, smask, lengths)

    # --- stage 3: generate + filter stems (static windows) ----------------
    stems3 = _windows(words, 3)  # (B, 6, 3)
    stems4 = _windows(words, 4)  # (B, 6, 4)

    second3 = stems3[:, :, 1]
    second4 = stems4[:, :, 1]
    is_infix3 = jnp.zeros_like(second3, dtype=bool)
    is_infix4 = jnp.zeros_like(second4, dtype=bool)
    for c in ab.INFIX_LETTERS:
        is_infix3 |= second3 == c
        is_infix4 |= second4 == c

    # Remove Infix: quad stem minus 2nd char → tri candidate.
    rm3 = jnp.stack([stems4[:, :, 0], stems4[:, :, 2], stems4[:, :, 3]], axis=-1)
    # Remove Infix: tri stem minus 2nd char → bi candidate.
    rm2 = jnp.stack([stems3[:, :, 0], stems3[:, :, 2]], axis=-1)
    # Restore Original Form: tri stem with 2nd char ا → و.
    rs3 = jnp.stack(
        [stems3[:, :, 0], jnp.full_like(second3, ab.WAW), stems3[:, :, 2]], axis=-1
    )

    # --- stage 4: dictionary compare (Pallas) ------------------------------
    # The three trilateral-shaped streams (direct, remove-infix, restored)
    # share the roots3 dictionary; fusing them into one kernel call cuts
    # pallas invocations 5 → 3 (§Perf: fewer dispatches, better tiling).
    tri_streams = jnp.concatenate([stems3, rm3, rs3], axis=1)  # (B, 18, 3)
    tri_found = _match_stream(tri_streams, bitmap3)  # (B, 18)
    m3, mrm3, mrs3 = tri_found[:, :6], tri_found[:, 6:12], tri_found[:, 12:]
    found = [
        m3 & valid3,
        _match_stream(stems4, bitmap4) & valid4,
        mrm3 & valid4 & is_infix4,
        _match_stream(rm2, bitmap2) & valid3 & is_infix3,
        mrs3 & valid3 & (second3 == ab.ALEF),
    ]

    # --- stage 5: extract root (priority select) ---------------------------
    pad3 = jnp.zeros(stems3.shape[:2] + (1,), jnp.int32)
    pad2 = jnp.zeros(stems3.shape[:2] + (2,), jnp.int32)
    cands = jnp.concatenate(
        [
            jnp.concatenate([stems3, pad3], -1),
            stems4,
            jnp.concatenate([rm3, pad3], -1),
            jnp.concatenate([rm2, pad2], -1),
            jnp.concatenate([rs3, pad3], -1),
        ],
        axis=1,
    )  # (B, 30, 4)
    flat_found = jnp.concatenate(found, axis=1)  # (B, 30)

    big = jnp.int32(NUM_STREAMS * ab.NUM_CUTS + 1)
    prio = jnp.arange(NUM_STREAMS * ab.NUM_CUTS, dtype=jnp.int32)[None, :]
    masked = jnp.where(flat_found, prio, big)
    best = jnp.argmin(masked, axis=1)  # (B,)
    any_found = jnp.take_along_axis(flat_found, best[:, None], axis=1)[:, 0]

    root = jnp.take_along_axis(cands, best[:, None, None], axis=1)[:, 0, :]
    root = jnp.where(any_found[:, None], root, 0)
    kind = jnp.where(any_found, best // ab.NUM_CUTS + 1, 0).astype(jnp.int32)
    cut = jnp.where(any_found, best % ab.NUM_CUTS, 0).astype(jnp.int32)
    return root, kind, cut


def make_stemmer(batch: int):
    """jit-wrapped ``stem_batch`` with pinned shapes, for AOT lowering."""

    def fn(words, lengths, bitmap2, bitmap3, bitmap4):
        return stem_batch(words, lengths, bitmap2, bitmap3, bitmap4)

    shapes = (
        jax.ShapeDtypeStruct((batch, ab.MAX_WORD), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((ab.BITMAP2,), jnp.int32),
        jax.ShapeDtypeStruct((ab.BITMAP3,), jnp.int32),
        jax.ShapeDtypeStruct((ab.BITMAP4,), jnp.int32),
    )
    return jax.jit(fn), shapes
