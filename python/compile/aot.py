"""AOT lowering: JAX model → HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the ``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Run as ``python -m compile.aot --out-dir ../artifacts`` from ``python/``
(or via ``make artifacts``). Python runs ONCE, at build time; the rust
binary is self-contained afterwards.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import alphabet as ab
from .kernels.match import match
from .model import make_stemmer

#: batch sizes baked into artifacts; the coordinator picks the best fit.
BATCH_SIZES = (1, 32, 256)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stemmer(batch: int) -> str:
    fn, shapes = make_stemmer(batch)
    return to_hlo_text(fn.lower(*shapes))


def lower_match_micro(m: int = 1536, r: int = ab.R3, length: int = 3) -> str:
    """Kernel-only artifact for the L1 microbenchmark."""
    fn = jax.jit(lambda s, d: (match(s, d),))
    lowered = fn.lower(
        jax.ShapeDtypeStruct((m, length), jnp.int32),
        jax.ShapeDtypeStruct((r, length), jnp.int32),
    )
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--batches", default=",".join(map(str, BATCH_SIZES)),
        help="comma-separated stemmer batch sizes",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "alphabet": ab.ALPHABET_SIZE,
        "max_word": ab.MAX_WORD,
        "dict_shapes": {"bitmap2": ab.BITMAP2, "bitmap3": ab.BITMAP3, "bitmap4": ab.BITMAP4},
        "artifacts": {},
    }

    for b in (int(x) for x in args.batches.split(",")):
        text = lower_stemmer(b)
        name = f"stemmer_b{b}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "kind": "stemmer",
            "batch": b,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    text = lower_match_micro()
    path = os.path.join(args.out_dir, "match_micro.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    manifest["artifacts"]["match_micro.hlo.txt"] = {
        "kind": "match_micro",
        "m": 1536,
        "r": ab.R3,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "bytes": len(text),
    }
    print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
